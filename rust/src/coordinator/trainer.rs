//! Training loops over the fused AOT train/eval/grad steps (Figure 7).
//!
//! [`Trainer`] drives the single-program `train_step_*` artifact: Rust
//! owns parameters + Adam state as host tensors, feeds them positionally
//! each step, and swaps in the returned state.  [`DistTrainer`] is the
//! multi-worker variant built on `grad_step_*` + [`GradSync`] + the
//! host [`Adam`] — the paper's hybrid data/expert-parallel training,
//! with identical math (pinned by `rust/tests/trainer_equivalence.rs`).
//! [`MoeLayerTrainer`] trains a builder-assembled expert-parallel
//! [`DistMoeLayer`] directly, logging the load-balance loss per step.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::{DistMoeLayer, ExpertMode, GradSync};
use crate::autotune::Autotuner;
use crate::comm::Comm;
use crate::config::{AutoConfig, CommConfig};
use crate::data::Batch;
use crate::error::{Error, Result};
use crate::fault::Membership;
use crate::metrics::Counters;
use crate::model::{load_tensors, save_tensors, Adam, ParamStore};
use crate::moe::LoadMonitor;
use crate::placement::{PlanDelta, Rebalancer};
use crate::runtime::{Executable, ModelEntry, Runtime};
use crate::tensor::{HostTensor, TensorF32};

/// Per-step statistics.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub step: u64,
    pub loss: f32,
    pub secs: f64,
}

/// Single-worker trainer over the fused train-step artifact.
pub struct Trainer {
    pub entry: ModelEntry,
    pub params: ParamStore,
    m: Vec<TensorF32>,
    v: Vec<TensorF32>,
    pub step: u64,
    train_exe: Arc<Executable>,
    eval_exe: Arc<Executable>,
}

impl Trainer {
    pub fn new(rt: &Runtime, model: &str, seed: u64) -> Result<Trainer> {
        let entry = rt.manifest.model(model)?.clone();
        let params = ParamStore::init(&entry, seed)?;
        let m = params.zeros_like();
        let v = params.zeros_like();
        let train_exe = rt.executable(&entry.train_step)?;
        let eval_exe = rt.executable(&entry.eval_step)?;
        // ABI check up front: 3 data inputs + 3n state inputs
        let n = params.len();
        if train_exe.meta.inputs.len() != 3 + 3 * n {
            return Err(Error::Abi {
                artifact: entry.train_step.clone(),
                msg: format!(
                    "train step wants {} inputs, registry has {n} params",
                    train_exe.meta.inputs.len()
                ),
            });
        }
        Ok(Trainer { entry, params, m, v, step: 0, train_exe, eval_exe })
    }

    /// One fused step: fwd + bwd + Adam inside XLA. Returns the loss.
    pub fn train_step(&mut self, batch: &Batch) -> Result<StepStats> {
        let t0 = std::time::Instant::now();
        self.step += 1;
        let n = self.params.len();
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(3 + 3 * n);
        inputs.push(HostTensor::I32(batch.tokens.clone()));
        inputs.push(HostTensor::I32(batch.targets.clone()));
        inputs.push(HostTensor::F32(TensorF32::scalar(self.step as f32)));
        for t in &self.params.tensors {
            inputs.push(HostTensor::F32(t.clone()));
        }
        for t in &self.m {
            inputs.push(HostTensor::F32(t.clone()));
        }
        for t in &self.v {
            inputs.push(HostTensor::F32(t.clone()));
        }
        let outputs = self.train_exe.run(&inputs)?;
        let mut it = outputs.into_iter();
        let loss = it.next().unwrap().into_f32()?.data[0];
        for i in 0..n {
            self.params.tensors[i] = it.next().unwrap().into_f32()?;
        }
        for i in 0..n {
            self.m[i] = it.next().unwrap().into_f32()?;
        }
        for i in 0..n {
            self.v[i] = it.next().unwrap().into_f32()?;
        }
        Ok(StepStats { step: self.step, loss, secs: t0.elapsed().as_secs_f64() })
    }

    /// Evaluation loss on a batch (no state change).
    pub fn eval(&self, batch: &Batch) -> Result<f32> {
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(2 + self.params.len());
        inputs.push(HostTensor::I32(batch.tokens.clone()));
        inputs.push(HostTensor::I32(batch.targets.clone()));
        for t in &self.params.tensors {
            inputs.push(HostTensor::F32(t.clone()));
        }
        let out = self.eval_exe.run(&inputs)?;
        Ok(out[0].as_f32()?.data[0])
    }

    /// FLOPs of one training step (fwd 1× + bwd 2×, matmuls only).
    pub fn step_flops(&self) -> f64 {
        let per_token = self
            .entry
            .config_usize("flops_per_token")
            .unwrap_or(0) as f64;
        let batch = self.entry.config_usize("batch").unwrap_or(1) as f64;
        let seq = self.entry.config_usize("seq").unwrap_or(1) as f64;
        3.0 * per_token * batch * seq
    }
}

/// Multi-worker trainer: per-worker `grad_step` + tag-aware sync + host
/// Adam. Each worker consumes its own shard of the batch stream.
pub struct DistTrainer {
    pub entry: ModelEntry,
    pub params: ParamStore,
    opt: Adam,
    grad_exe: Arc<Executable>,
    sync: GradSync,
    pub step: u64,
    /// Checkpoint every this many steps (0 = off).
    ckpt_interval: usize,
    ckpt_dir: Option<String>,
    /// `[auto]` online tuner, when attached (see `crate::autotune`).
    autotuner: Option<Autotuner>,
}

impl DistTrainer {
    pub fn new(
        rt: &Runtime,
        model: &str,
        seed: u64,
        workers: usize,
        lr: f32,
    ) -> Result<DistTrainer> {
        Self::with_comm(rt, model, seed, workers, 0, lr, &CommConfig::default())
    }

    /// [`DistTrainer::new`] with the `[comm]` section's gradient-sync
    /// knobs: `grad_overlap` switches the step to the bucketed
    /// nonblocking all-reduce pipelined against host Adam, `bucket_kb`
    /// sizes the buckets, `grad_shard = "zero"` shards the Adam state
    /// (this rank holds only its owned slice of every world-replicated
    /// tensor's moments — which is why the builder needs `rank`).
    /// Parameters stay bit-identical in every mode.
    pub fn with_comm(
        rt: &Runtime,
        model: &str,
        seed: u64,
        workers: usize,
        rank: usize,
        lr: f32,
        comm_cfg: &CommConfig,
    ) -> Result<DistTrainer> {
        let entry = rt.manifest.model(model)?.clone();
        let params = ParamStore::init(&entry, seed)?;
        let grad_exe = rt.executable(&entry.grad_step)?;
        // In this fused-graph emulation every worker holds all experts,
        // so expert grads are averaged (mathematically identical to one
        // global expert fed all routed tokens — see coordinator docs).
        let sync = GradSync::world(workers, ExpertMode::Replicated).comm_config(comm_cfg);
        let opt = if sync.shard {
            // ZeRO: moment state shrinks to the owned shard of every
            // World-scope slot.  The layout depends only on (shapes,
            // tags, rank, topology) — it is fixed here, before any
            // collective runs, and checkpoints persist exactly the
            // owned slices (resume needs the same world + topology;
            // anything else fails the load-time shape check loudly).
            let tags: Vec<_> = params.entries.iter().map(|e| e.tag).collect();
            let topo = comm_cfg.topology_for(workers.max(1))?;
            let shard = sync.shard_plan(&params.tensors, &tags, &topo, rank);
            Adam::new_sharded(&params.tensors, lr, &shard)?
        } else {
            Adam::new(&params.tensors, lr)
        };
        Ok(DistTrainer {
            entry,
            params,
            opt,
            grad_exe,
            sync,
            step: 0,
            ckpt_interval: 0,
            ckpt_dir: None,
            autotuner: None,
        })
    }

    /// Attach the `[auto]` online tuner (see `crate::autotune`).  Every
    /// rank must attach one built from identical config — the
    /// calibrate/search protocol is collective.  In live mode this
    /// trainer applies the step-boundary-safe knob it owns
    /// (`bucket_kb`); everything else stays a logged recommendation.
    pub fn with_autotune(
        mut self,
        auto: AutoConfig,
        comm_cfg: &CommConfig,
    ) -> Result<DistTrainer> {
        let workers = self.sync.dp_group.len();
        self.autotuner = Some(Autotuner::new(auto, comm_cfg, workers)?);
        Ok(self)
    }

    /// The attached tuner, read-only (test + bench introspection).
    pub fn autotuner(&self) -> Option<&Autotuner> {
        self.autotuner.as_ref()
    }

    /// Enable periodic checkpointing: every `interval` steps each rank
    /// writes `rank<r>.fmoe` under `dir` atomically (`[fault]
    /// ckpt_interval` / `ckpt_dir`).  `interval = 0` disables.
    pub fn with_checkpointing(mut self, interval: usize, dir: &str) -> DistTrainer {
        self.ckpt_interval = interval;
        self.ckpt_dir = (!dir.is_empty()).then(|| dir.to_string());
        self
    }

    /// Write this rank's full state — params, Adam moments, counters —
    /// to `rank<r>.fmoe` under `dir` via the atomic tmp+rename writer.
    ///
    /// Under `grad_shard = "zero"` the `m{i}`/`v{i}` tensors are this
    /// rank's *owned slices* (flat `[shard_len]` tensors), so the set
    /// of per-rank checkpoints together holds exactly one copy of the
    /// optimizer state.  Resume needs the same world size and topology
    /// — a mismatched shard layout fails the load-time shape check
    /// rather than silently mis-slicing.
    pub fn save_checkpoint(&self, dir: &str, rank: usize) -> Result<()> {
        let meta = TensorF32::from_vec(
            &[2],
            vec![self.opt.step as f32, self.step as f32],
        )?;
        let mut named: Vec<(String, &TensorF32)> =
            Vec::with_capacity(3 * self.params.len() + 1);
        for (e, t) in self.params.entries.iter().zip(&self.params.tensors) {
            named.push((format!("p.{}", e.name), t));
        }
        for (i, t) in self.opt.m.iter().enumerate() {
            named.push((format!("m{i}"), t));
        }
        for (i, t) in self.opt.v.iter().enumerate() {
            named.push((format!("v{i}"), t));
        }
        named.push(("meta".into(), &meta));
        save_tensors(MoeLayerTrainer::ckpt_path(dir, rank), &named)
    }

    /// Restore this rank's state from its `rank<r>.fmoe` under `dir`
    /// (inverse of [`Self::save_checkpoint`]; the `--resume` path of
    /// `fastmoe dist-moe`'s fused-trainer mode).
    pub fn load_checkpoint(&mut self, dir: &str, rank: usize) -> Result<()> {
        let path = MoeLayerTrainer::ckpt_path(dir, rank);
        let tensors = load_tensors(&path)?;
        let find = |key: &str| -> Result<&TensorF32> {
            tensors
                .iter()
                .find(|(n, _)| n == key)
                .map(|(_, t)| t)
                .ok_or_else(|| {
                    Error::Checkpoint(format!("`{key}` missing from {path:?}"))
                })
        };
        let copy = |src: &TensorF32, dst: &mut TensorF32, key: &str| -> Result<()> {
            if src.shape != dst.shape {
                return Err(Error::Checkpoint(format!(
                    "`{key}`: checkpoint shape {:?} vs model {:?}",
                    src.shape, dst.shape
                )));
            }
            dst.data.copy_from_slice(&src.data);
            Ok(())
        };
        let names: Vec<String> =
            self.params.entries.iter().map(|e| e.name.clone()).collect();
        for (name, dst) in names.iter().zip(self.params.tensors.iter_mut()) {
            let key = format!("p.{name}");
            copy(find(&key)?, dst, &key)?;
        }
        for (i, dst) in self.opt.m.iter_mut().enumerate() {
            let key = format!("m{i}");
            copy(find(&key)?, dst, &key)?;
        }
        for (i, dst) in self.opt.v.iter_mut().enumerate() {
            let key = format!("v{i}");
            copy(find(&key)?, dst, &key)?;
        }
        let meta = find("meta")?;
        if meta.data.len() != 2 {
            return Err(Error::Checkpoint("bad meta tensor".into()));
        }
        self.opt.step = meta.data[0] as u64;
        self.step = meta.data[1] as u64;
        Ok(())
    }

    /// One synchronous distributed step. Returns the *global* mean loss.
    pub fn train_step(&mut self, comm: &mut impl Comm, batch: &Batch) -> Result<f32> {
        let t0 = std::time::Instant::now();
        self.step += 1;
        let n = self.params.len();
        let mut inputs: Vec<HostTensor> = Vec::with_capacity(2 + n);
        inputs.push(HostTensor::I32(batch.tokens.clone()));
        inputs.push(HostTensor::I32(batch.targets.clone()));
        for t in &self.params.tensors {
            inputs.push(HostTensor::F32(t.clone()));
        }
        let out = self.grad_exe.run(&inputs)?;
        let mut it = out.into_iter();
        let local_loss = it.next().unwrap().into_f32()?.data[0];
        let mut grads: Vec<TensorF32> = Vec::with_capacity(n);
        for _ in 0..n {
            grads.push(it.next().unwrap().into_f32()?);
        }

        // tag-aware gradient synchronisation (the paper's §3.2 module)
        let tags: Vec<_> = self.params.entries.iter().map(|e| e.tag).collect();
        if self.sync.shard {
            // ZeRO: one fused schedule per bucket — reduce-scatter,
            // shard-local Adam on the owned slice, all-gather of the
            // *updated params* — with later buckets' rounds in flight
            // while earlier buckets step (see GradSync::sync_zero).
            let t = std::time::Instant::now();
            self.sync.sync_zero(
                comm,
                &mut grads,
                &tags,
                &mut self.params.tensors,
                &mut self.opt,
            )?;
            comm.counters()
                .add("phase_gradsync_ns", t.elapsed().as_nanos() as u64);
        } else if self.sync.overlap && comm.size() > 1 {
            // Overlapped: the shared launch/complete protocol, with
            // host Adam as the per-bucket hook — while bucket i's
            // parameters step, each later bucket has its current ring
            // round in flight (rounds advance inside the waits, one
            // outstanding round per bucket).
            let t = std::time::Instant::now();
            self.opt.begin_step();
            let (opt, params) = (&mut self.opt, &mut self.params);
            self.sync.sync_overlapped(comm, &mut grads, &tags, |b, grads| {
                for &i in &b.indices {
                    opt.update_slot(i, &mut params.tensors[i], &grads[i])?;
                }
                Ok(())
            })?;
            comm.counters()
                .add("phase_gradsync_ns", t.elapsed().as_nanos() as u64);
        } else {
            let t = std::time::Instant::now();
            self.sync.sync(comm, &mut grads, &tags)?;
            comm.counters()
                .add("phase_gradsync_ns", t.elapsed().as_nanos() as u64);
            // host Adam (bit-compatible with the fused in-graph update)
            let t = std::time::Instant::now();
            self.opt.update(&mut self.params.tensors, &grads)?;
            comm.counters()
                .add("phase_opt_ns", t.elapsed().as_nanos() as u64);
        }
        if comm.size() > 1 {
            let bytes: usize =
                self.params.tensors.iter().map(|t| t.data.len() * 4).sum();
            comm.counters().add("grad_sync_bytes", bytes as u64);
        }

        if self.ckpt_interval > 0 && self.step % self.ckpt_interval as u64 == 0 {
            if let Some(dir) = self.ckpt_dir.clone() {
                self.save_checkpoint(&dir, comm.rank())?;
            }
        }

        // global mean loss for logging
        let mut loss_buf = vec![local_loss];
        comm.all_reduce_sum(&mut loss_buf)?;
        let loss = loss_buf[0] / comm.size() as f32;
        self.autotune_observe(comm, t0.elapsed().as_secs_f64())?;
        Ok(loss)
    }

    /// Feed the completed step to the tuner; when a calibration window
    /// just closed, report the recommendation (rank 0) and in live mode
    /// apply the step-boundary-safe knob this trainer owns
    /// (`bucket_kb`).  The tuner's outcome is rank-agreed, so every
    /// rank re-buckets at the same boundary — and bucketing never
    /// changes parameter bits, only the sync schedule.
    fn autotune_observe(&mut self, comm: &mut impl Comm, secs: f64) -> Result<()> {
        let Some(tuner) = self.autotuner.as_mut() else {
            return Ok(());
        };
        let snap = comm.counters().clone();
        let Some(outcome) = tuner.observe(comm, &snap, secs)? else {
            return Ok(());
        };
        if tuner.live() {
            let k = outcome.live.knobs;
            self.sync.bucket_bytes = k.bucket_kb * 1024;
            tuner.note_applied(k);
        }
        if comm.rank() == 0 {
            eprintln!(
                "[auto] dist step {}: predicted best {:.3} ms/step — \
                 recommended [comm]:\n{}",
                self.step,
                outcome.best.predicted * 1e3,
                outcome.best.toml_snippet()
            );
        }
        Ok(())
    }
}

/// Per-step statistics of the expert-parallel layer trainer, including
/// the §6 load-balance signal.
#[derive(Clone, Copy, Debug)]
pub struct MoeStepStats {
    pub step: u64,
    /// Energy loss `0.5 · mean(y²)` the demo objective minimises.
    pub loss: f32,
    /// GShard auxiliary balance loss of this step's routing (1.0 is
    /// the balanced minimum).
    pub balance: f64,
    /// Running max/mean expert-load ratio from the monitor.
    pub imbalance: f64,
    /// Matmul FLOPs of the step (fwd + bwd ≈ 3× fwd).
    pub flops: f64,
    pub secs: f64,
}

/// Trains one expert-parallel [`DistMoeLayer`] (gate GEMM + expert
/// shard) against the energy objective `0.5 · mean(y²)` — the
/// layer-level training loop used by `fastmoe dist-moe` and the
/// `distributed_moe` example.
///
/// Every step records per-expert token counts into the [`LoadMonitor`]
/// and reports the balance loss, so gate policies can be compared on
/// load balance directly from the step log.
///
/// With [`MoeLayerTrainer::with_placement`] the trainer also closes the
/// load→layout loop: a [`Rebalancer`] watches the same kept counts and,
/// at window boundaries, agrees on a [`PlanDelta`] across ranks which
/// the layer executes between steps (shadow replication or expert
/// migration — see `crate::placement`).  `DistTrainer` has no placement
/// surface by construction: its fused-graph emulation replicates every
/// expert on every worker, so there is nothing to re-shard.
pub struct MoeLayerTrainer {
    pub layer: DistMoeLayer,
    opt: Adam,
    pub monitor: LoadMonitor,
    pub step: u64,
    rebalancer: Option<Rebalancer>,
    /// Agreed membership while in degraded mode (`None` = full strength).
    degraded: Option<Membership>,
    /// Checkpoint every this many steps (0 = off).
    ckpt_interval: usize,
    ckpt_dir: Option<String>,
    /// `[auto]` online tuner, when attached (see `crate::autotune`).
    autotuner: Option<Autotuner>,
}

impl MoeLayerTrainer {
    pub fn new(layer: DistMoeLayer, lr: f32) -> MoeLayerTrainer {
        let shapes: Vec<TensorF32> = layer
            .params()
            .into_iter()
            .map(|(_, t)| TensorF32::zeros(&t.shape))
            .collect();
        let opt = if layer.grad_shard {
            // ZeRO (`[comm] grad_shard = "zero"`): the replicated gate
            // slots hold only this rank's owned slice of moment state;
            // expert slots keep full state (their grads are local-final
            // and never reduced).  The shard layout follows the layer's
            // topology, which the comm wrapper shares by construction
            // (both come from the same `[comm]` section); a mismatch
            // fails loudly inside `apply_grads_zero`.
            let shard: Vec<Option<std::ops::Range<usize>>> = shapes
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    (i < 2).then(|| {
                        crate::comm::zero_shard_range(
                            layer.topology(),
                            layer.rank,
                            t.data.len(),
                        )
                    })
                })
                .collect();
            Adam::new_sharded(&shapes, lr, &shard)
                .expect("gate shard ranges lie inside the params by construction")
        } else {
            Adam::new(&shapes, lr)
        };
        let monitor = LoadMonitor::new(layer.workers * layer.ne_local);
        MoeLayerTrainer {
            layer,
            opt,
            monitor,
            step: 0,
            rebalancer: None,
            degraded: None,
            ckpt_interval: 0,
            ckpt_dir: None,
            autotuner: None,
        }
    }

    /// Attach the `[auto]` online tuner (see `crate::autotune`): every
    /// rank must attach one built from identical config — the
    /// calibrate/search/apply protocol is collective, like the
    /// rebalancer's.  `comm_cfg` must be the `[comm]` section the layer
    /// was built from.  In live mode the trainer applies the
    /// step-boundary-safe knobs (`chunks`, `chunk_policy`) in lockstep;
    /// restart-only knobs stay logged recommendations.
    pub fn with_autotune(
        mut self,
        auto: AutoConfig,
        comm_cfg: &CommConfig,
    ) -> Result<MoeLayerTrainer> {
        self.autotuner =
            Some(Autotuner::new(auto, comm_cfg, self.layer.workers)?);
        Ok(self)
    }

    /// The attached tuner, read-only (test + bench introspection).
    pub fn autotuner(&self) -> Option<&Autotuner> {
        self.autotuner.as_ref()
    }

    /// Attach a placement [`Rebalancer`]; every rank must attach an
    /// identically-configured one (the decision protocol is collective).
    pub fn with_placement(mut self, rebalancer: Rebalancer) -> MoeLayerTrainer {
        self.rebalancer = Some(rebalancer);
        self
    }

    /// Enable periodic checkpointing: every `interval` steps each rank
    /// writes `rank<r>.fmoe` under `dir` via the atomic tmp+rename path
    /// (`[fault] ckpt_interval` / `ckpt_dir`).  `interval = 0` disables.
    pub fn with_checkpointing(mut self, interval: usize, dir: &str) -> MoeLayerTrainer {
        self.ckpt_interval = interval;
        self.ckpt_dir = (!dir.is_empty()).then(|| dir.to_string());
        self
    }

    /// Apply a placement delta outside the rebalancer's own cadence —
    /// the deterministic hook the equivalence tests drive (the trainer
    /// owns the optimiser, whose Adam state migrates with the expert).
    pub fn force_delta(&mut self, comm: &mut impl Comm, delta: &PlanDelta) -> Result<()> {
        self.layer.apply_delta(comm, delta, &mut self.opt)
    }

    /// The trainer-owned optimiser, read-only — the placement
    /// equivalence tests compare migrated Adam state bit-for-bit
    /// against an unmigrated reference.
    pub fn optimizer(&self) -> &Adam {
        &self.opt
    }

    /// One forward + backward + optimiser step over `x: [nb, dm]`.
    pub fn train_step(
        &mut self,
        comm: &mut impl Comm,
        x: TensorF32,
        counters: &mut Counters,
    ) -> Result<MoeStepStats> {
        let t0 = std::time::Instant::now();
        self.step += 1;
        let (y, state) = self.layer.forward(comm, x, counters)?;
        let n = y.data.len() as f32;
        let loss = 0.5 * y.data.iter().map(|v| v * v).sum::<f32>() / n;
        // d(0.5·mean(y²))/dy = y / numel
        let mut dy = y;
        for v in dy.data.iter_mut() {
            *v /= n;
        }
        let mut grads = self.layer.backward(comm, &state, &dy, counters)?;
        // Gate params are replicated (tag: world): average their grads
        // across workers before stepping, or the replicas diverge.
        // Expert shards are `none`-tagged — each shard already saw every
        // token routed to it, so its local grads are final.  With
        // `[comm] grad_overlap` the backward already flew the gate-grad
        // bucket during the expert backward (`grads.gate_synced`) —
        // same rings, same scale, bit-identical result.  In degraded
        // mode the reduction runs over the survivor sub-group instead,
        // while the quarantined zombie burns the matching seqs (tag
        // schedules stay world-aligned) and zeroes the balance-loss gate
        // grads its drained forward still produced.
        let ws = comm.size();
        let gate_bytes = ((grads.dwg.data.len() + grads.dbg.data.len()) * 4) as u64;
        let sync_t = crate::metrics::Phase::start();
        match self.degraded.clone() {
            Some(m) if m.is_dead(self.layer.rank) => {
                // `all_reduce_sum_group` consumes one seq per call —
                // except in the degenerate single-survivor group, where
                // it early-returns before drawing any.
                if m.survivors().len() > 1 {
                    comm.next_seq();
                    comm.next_seq();
                }
                for v in grads.dwg.data.iter_mut() {
                    *v = 0.0;
                }
                for v in grads.dbg.data.iter_mut() {
                    *v = 0.0;
                }
            }
            Some(m) => {
                let g = m.survivors();
                comm.all_reduce_sum_group(&mut grads.dwg.data, &g)?;
                comm.all_reduce_sum_group(&mut grads.dbg.data, &g)?;
                let scale = 1.0 / g.len() as f32;
                for v in grads.dwg.data.iter_mut() {
                    *v *= scale;
                }
                for v in grads.dbg.data.iter_mut() {
                    *v *= scale;
                }
            }
            // ZeRO gate sync happens inside `apply_grads_zero` below
            // (reduce-scatter + shard Adam + gather, one schedule).
            None if ws > 1 && !grads.gate_synced && !self.layer.grad_shard => {
                comm.all_reduce_sum(&mut grads.dwg.data)?;
                comm.all_reduce_sum(&mut grads.dbg.data)?;
                let scale = 1.0 / ws as f32;
                for v in grads.dwg.data.iter_mut() {
                    *v *= scale;
                }
                for v in grads.dbg.data.iter_mut() {
                    *v *= scale;
                }
            }
            None => {}
        }
        // visible (unhidden) gate-sync wire time; under grad_overlap
        // the bucket flew during the expert backward, so ~0 lands here
        // — exactly the phase view the autotune calibrator wants
        sync_t.stop(counters, "phase_gradsync_ns");
        if ws > 1 {
            counters.add("grad_sync_bytes", gate_bytes);
        }
        self.monitor.record(&state.counts_kept);
        let opt_t = crate::metrics::Phase::start();
        if self.layer.grad_shard {
            // the ZeRO schedule fuses its sync into the optimiser step,
            // so its rings land in this phase rather than the one above
            self.layer.apply_grads_zero(comm, &mut self.opt, &grads)?;
        } else {
            self.layer.apply_grads(&mut self.opt, &grads)?;
        }
        opt_t.stop(counters, "phase_opt_ns");
        // Keep shadow replicas bit-identical to their owners (a no-op
        // without shadows), then let the rebalancer — if any — agree on
        // and execute a layout change at this step boundary.
        self.layer.sync_shadows(comm, &grads, &self.opt)?;
        if let Some(reb) = self.rebalancer.as_mut() {
            reb.observe(&state.counts_kept);
            let delta = reb.maybe_rebalance(comm, self.layer.placement())?;
            if let Some(delta) = delta {
                self.layer.apply_delta(comm, &delta, &mut self.opt)?;
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        self.autotune_observe(comm, counters, secs)?;
        let stats = MoeStepStats {
            step: self.step,
            loss,
            balance: state.balance,
            imbalance: self.monitor.imbalance(),
            flops: 3.0 * self.layer.flops(&state),
            secs,
        };
        // hand the step's padded batch + combine input back to the
        // layer's arena so the next step allocates nothing
        self.layer.recycle(state);
        self.maybe_checkpoint()?;
        Ok(stats)
    }

    /// Feed the completed step to the tuner; when a calibration window
    /// just closed, report the recommendation (rank 0) and in live mode
    /// apply the step-boundary-safe knobs in lockstep.  Safe because
    /// the tuner's outcome derives only from rank-agreed data (the same
    /// invariant `moe::agree_chunks` and the rebalancer rely on):
    /// every rank writes the same `chunks`/`chunk_policy` at the same
    /// boundary, and the chunked schedule is bit-identical to blocking
    /// for any chunk count by construction.
    fn autotune_observe(
        &mut self,
        comm: &mut impl Comm,
        counters: &Counters,
        secs: f64,
    ) -> Result<()> {
        let Some(tuner) = self.autotuner.as_mut() else {
            return Ok(());
        };
        let Some(outcome) = tuner.observe(comm, counters, secs)? else {
            return Ok(());
        };
        let live = tuner.live();
        if live {
            let k = outcome.live.knobs;
            self.layer.chunks = if k.chunks == 0 {
                0 // adaptive: sched() resolves it per step
            } else {
                k.chunks.clamp(1, self.layer.workers)
            };
            self.layer.set_chunk_policy(k.chunk_policy);
            tuner.note_applied(k);
        }
        if comm.rank() == 0 {
            let applied = if live {
                format!(
                    " (applied: chunks = {}, chunk_policy = \"{}\")",
                    outcome.live.knobs.chunks,
                    outcome.live.knobs.chunk_policy.as_str()
                )
            } else {
                String::new()
            };
            eprintln!(
                "[auto] step {}: predicted best {:.3} ms/step{applied} — \
                 recommended [comm]:\n{}",
                self.step,
                outcome.best.predicted * 1e3,
                outcome.best.toml_snippet()
            );
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Elastic fault recovery (`crate::fault`): degraded-mode entry,
    // periodic checkpoints, and the rejoin choreography.
    // ------------------------------------------------------------------

    /// Current degraded-mode membership, if any.
    pub fn degraded(&self) -> Option<&Membership> {
        self.degraded.as_ref()
    }

    /// Enter degraded mode at a step boundary.  **Every rank** calls
    /// this with the same agreed [`Membership`] (see
    /// [`crate::fault::agree_membership`]): the layer quarantines the
    /// dead rank (shadow-replica failover + score-masked drops), the
    /// rebalancer freezes its windows and re-binds its all-reduce to the
    /// survivor sub-group, and subsequent gate syncs run group-wise.
    pub fn degrade(&mut self, m: &Membership) -> Result<()> {
        if self.layer.grad_overlap {
            return Err(Error::Config(
                "degraded mode needs blocking gradient sync \
                 ([comm] grad_overlap = false): the overlapped gate \
                 bucket rings span the full world"
                    .into(),
            ));
        }
        if self.layer.grad_shard {
            // Survivors hold none of the dead rank's owned moment
            // slices, so degraded-mode training would continue with a
            // hole in the optimizer state.  Re-sharding those slices
            // onto survivors at the degrade boundary is future work
            // (see ROADMAP); until then ZeRO runs fail fast here and
            // `[fault] recover = "abort"` restarts from checkpoints,
            // which persist exactly the owned slices per rank.
            return Err(Error::Config(
                "degraded mode cannot re-shard ZeRO optimizer state \
                 ([comm] grad_shard = \"none\", or [fault] recover = \
                 \"abort\"): the dead rank's owned moment slices have \
                 no surviving copy"
                    .into(),
            ));
        }
        if m.dead.len() != 1 {
            return Err(Error::Config(format!(
                "degraded mode supports exactly one dead rank, membership has {:?}",
                m.dead
            )));
        }
        self.layer.fail_rank(m.dead[0])?;
        if let Some(reb) = self.rebalancer.as_mut() {
            reb.freeze(true);
            reb.bind_group(Some(m.survivors()));
        }
        self.degraded = Some(m.clone());
        Ok(())
    }

    /// Per-rank checkpoint path under `dir`.
    fn ckpt_path(dir: &str, rank: usize) -> PathBuf {
        Path::new(dir).join(format!("rank{rank}.fmoe"))
    }

    /// Write this rank's full training state — layer params, Adam
    /// moments, and the `[opt.step, trainer.step]` counters — to
    /// `rank<r>.fmoe` under `dir` via the atomic tmp+rename writer.
    pub fn save_checkpoint(&self, dir: &str) -> Result<()> {
        let meta = TensorF32::from_vec(
            &[2],
            vec![self.opt.step as f32, self.step as f32],
        )?;
        let params = self.layer.params();
        let mut named: Vec<(String, &TensorF32)> =
            Vec::with_capacity(3 * params.len() + 1);
        for (i, (name, t)) in params.iter().enumerate() {
            named.push((format!("p{i}.{name}"), t));
        }
        for (i, t) in self.opt.m.iter().enumerate() {
            named.push((format!("m{i}"), t));
        }
        for (i, t) in self.opt.v.iter().enumerate() {
            named.push((format!("v{i}"), t));
        }
        named.push(("meta".into(), &meta));
        save_tensors(Self::ckpt_path(dir, self.layer.rank), &named)
    }

    /// Restore this rank's state from its `rank<r>.fmoe` under `dir`
    /// (inverse of [`Self::save_checkpoint`]; shapes must match).
    pub fn load_checkpoint(&mut self, dir: &str) -> Result<()> {
        let path = Self::ckpt_path(dir, self.layer.rank);
        let tensors = load_tensors(&path)?;
        let find = |key: &str| -> Result<&TensorF32> {
            tensors
                .iter()
                .find(|(n, _)| n == key)
                .map(|(_, t)| t)
                .ok_or_else(|| {
                    Error::Checkpoint(format!("`{key}` missing from {path:?}"))
                })
        };
        let copy = |src: &TensorF32, dst: &mut TensorF32, key: &str| -> Result<()> {
            if src.shape != dst.shape {
                return Err(Error::Checkpoint(format!(
                    "`{key}`: checkpoint shape {:?} vs model {:?}",
                    src.shape, dst.shape
                )));
            }
            dst.data.copy_from_slice(&src.data);
            Ok(())
        };
        for (i, (name, dst)) in self.layer.params_mut().into_iter().enumerate() {
            let key = format!("p{i}.{name}");
            copy(find(&key)?, dst, &key)?;
        }
        for (i, dst) in self.opt.m.iter_mut().enumerate() {
            let key = format!("m{i}");
            copy(find(&key)?, dst, &key)?;
        }
        for (i, dst) in self.opt.v.iter_mut().enumerate() {
            let key = format!("v{i}");
            copy(find(&key)?, dst, &key)?;
        }
        let meta = find("meta")?;
        if meta.data.len() != 2 {
            return Err(Error::Checkpoint("bad meta tensor".into()));
        }
        // exact for any plausible step count (f32 is integral ≤ 2^24)
        self.opt.step = meta.data[0] as u64;
        self.step = meta.data[1] as u64;
        Ok(())
    }

    /// Periodic-checkpoint hook, called at the end of every step.  A
    /// quarantined zombie skips its turns: its drained state is not the
    /// real training trajectory, and overwriting would destroy the
    /// genuinely pre-death checkpoint its own rejoin restores from.
    fn maybe_checkpoint(&mut self) -> Result<()> {
        if self.ckpt_interval == 0 || self.step % self.ckpt_interval as u64 != 0 {
            return Ok(());
        }
        let Some(dir) = self.ckpt_dir.clone() else { return Ok(()) };
        if let Some(m) = &self.degraded {
            if m.is_dead(self.layer.rank) {
                return Ok(());
            }
        }
        self.save_checkpoint(&dir)
    }

    /// The rejoin choreography — **every rank** calls this at the same
    /// step boundary to bring the quarantined rank back and return to
    /// full strength:
    ///
    /// 1. the dead rank restores params + Adam slots + counters from its
    ///    latest periodic checkpoint (skipped when none exists yet);
    /// 2. every shadow-covered expert the dead rank owns — whose
    ///    replicas kept training past that checkpoint — streams back
    ///    from its lowest live host
    ///    ([`DistMoeLayer::transfer_slots_from_shadows`]);
    /// 3. the replicated gate (+ its Adam slots + both step counters) is
    ///    broadcast from the lowest survivor; only the dead rank applies
    ///    it, fast-forwarding to the survivors' trajectory;
    /// 4. the quarantine lifts everywhere: routing, masks, rebalancer
    ///    windows and gate syncs return to the full world.
    pub fn rejoin_restore(
        &mut self,
        comm: &mut impl Comm,
        ckpt_dir: Option<&str>,
    ) -> Result<()> {
        let Some(m) = self.degraded.clone() else {
            return Err(Error::Config("rejoin_restore: not in degraded mode".into()));
        };
        let dead = m.dead[0];
        let me_dead = self.layer.rank == dead;
        if me_dead {
            if let Some(dir) = ckpt_dir {
                if Self::ckpt_path(dir, self.layer.rank).exists() {
                    self.load_checkpoint(dir)?;
                }
            }
        }
        self.layer.transfer_slots_from_shadows(comm, &mut self.opt)?;
        // Gate broadcast: wg ++ bg ++ Adam m/v of both ++ counters.  All
        // ranks run the collective (one seq); only the dead rank lands it.
        let root = m.survivors()[0];
        let mut buf: Vec<f32> = Vec::new();
        buf.extend_from_slice(&self.layer.wg.data);
        buf.extend_from_slice(&self.layer.bg.data);
        for slot in 0..2 {
            buf.extend_from_slice(&self.opt.m[slot].data);
        }
        for slot in 0..2 {
            buf.extend_from_slice(&self.opt.v[slot].data);
        }
        buf.push(self.opt.step as f32);
        buf.push(self.step as f32);
        comm.broadcast(&mut buf, root)?;
        if me_dead {
            let mut pos = 0usize;
            let mut take = |dst: &mut Vec<f32>| {
                dst.copy_from_slice(&buf[pos..pos + dst.len()]);
                pos += dst.len();
            };
            take(&mut self.layer.wg.data);
            take(&mut self.layer.bg.data);
            take(&mut self.opt.m[0].data);
            take(&mut self.opt.m[1].data);
            take(&mut self.opt.v[0].data);
            take(&mut self.opt.v[1].data);
            self.opt.step = buf[pos] as u64;
            self.step = buf[pos + 1] as u64;
        }
        self.layer.restore_rank()?;
        if let Some(reb) = self.rebalancer.as_mut() {
            reb.freeze(false);
            reb.bind_group(None);
        }
        self.degraded = None;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{BatchIter, Corpus};
    use crate::runtime::Runtime;

    fn rt() -> Option<Arc<Runtime>> {
        Runtime::open_default().ok().map(Arc::new)
    }

    #[test]
    fn fused_trainer_decreases_loss() {
        let Some(rt) = rt() else { return };
        let mut tr = Trainer::new(&rt, "gpt_moe", 1).unwrap();
        let vocab = tr.entry.config_usize("vocab").unwrap();
        let seq = tr.entry.config_usize("seq").unwrap();
        let batch = tr.entry.config_usize("batch").unwrap();
        let corpus = Corpus::synthetic(vocab, 50_000, 11);
        let mut it = BatchIter::new(&corpus, batch, seq, 2);
        let first = tr.train_step(&it.next_batch()).unwrap().loss;
        let mut last = first;
        for _ in 0..8 {
            last = tr.train_step(&it.next_batch()).unwrap().loss;
        }
        assert!(
            last < first,
            "loss did not decrease: first={first} last={last}"
        );
        assert!(tr.params.all_finite());
    }

    #[test]
    fn eval_is_pure() {
        let Some(rt) = rt() else { return };
        let tr = Trainer::new(&rt, "gpt_dense", 1).unwrap();
        let vocab = tr.entry.config_usize("vocab").unwrap();
        let seq = tr.entry.config_usize("seq").unwrap();
        let batch = tr.entry.config_usize("batch").unwrap();
        let corpus = Corpus::synthetic(vocab, 20_000, 5);
        let mut it = BatchIter::new(&corpus, batch, seq, 3);
        let b = it.next_batch();
        let l1 = tr.eval(&b).unwrap();
        let l2 = tr.eval(&b).unwrap();
        assert_eq!(l1, l2);
        // near-uniform at init
        assert!((l1 - (vocab as f32).ln()).abs() < 0.7, "l1={l1}");
    }
}
