//! Step-persistent buffer pools — the allocation-free hot path's arena.
//!
//! Every MoE iteration used to allocate (and zero) the same family of
//! buffers from scratch: the padded expert batch, the per-chunk compute
//! staging, the cotangent container, and one send/receive staging `Vec`
//! per peer.  [`BufferPool`] recycles all of them across steps: buffers
//! are keyed by a `role` (a static str naming the buffer's job) and
//! reused capacity-based — a request is a *hit* when some pooled buffer
//! of that role already has enough capacity, a *miss* when the pool has
//! to touch the allocator (fresh buffer or capacity growth).  After a
//! warm-up step or two every steady-state request hits, which is what
//! the `zero_copy_regression` test pins.
//!
//! The pool is deliberately dumb: no sizing classes, no cross-role
//! sharing, best-fit within a role.  Roles keep buffers with very
//! different size distributions from pessimising each other; where one
//! role must host mixed sizes anyway (wire staging receives both row
//! payloads and tiny count messages back from the comm backend),
//! best-fit takes plus [`BufferPool::give`]'s size-aware eviction keep
//! small buffers from starving large requests.
//!
//! Counters (`hits`/`misses`/`alloc_bytes`) are surfaced by
//! `DistMoeLayer` through the per-step [`crate::metrics::Counters`]
//! (`pool_hits` / `pool_misses` / `pool_alloc_bytes`), so benches and
//! the regression tests read them with no extra plumbing.

use std::collections::BTreeMap;

use super::TensorF32;
use crate::error::Result;

/// Aggregate pool counters, cheap to snapshot (the per-step deltas the
/// layer reports are differences of two of these).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Requests served entirely from pooled capacity.
    pub hits: u64,
    /// Requests that had to allocate (fresh buffer or growth).
    pub misses: u64,
    /// Bytes obtained from the allocator, cumulative.
    pub alloc_bytes: u64,
}

impl PoolStats {
    /// `self - earlier`, for per-step deltas.
    pub fn since(&self, earlier: &PoolStats) -> PoolStats {
        PoolStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            alloc_bytes: self.alloc_bytes - earlier.alloc_bytes,
        }
    }
}

/// Maximum free buffers retained per role; extras are dropped so a
/// one-off burst (e.g. a huge ragged step) can't pin memory forever.
const MAX_FREE_PER_ROLE: usize = 32;

/// A role-keyed, capacity-based `Vec<f32>` arena (see module docs).
#[derive(Debug, Default)]
pub struct BufferPool {
    /// `false` turns every take into a plain allocation (the
    /// `[comm] pool = false` A/B knob); give() drops.
    enabled: bool,
    free: BTreeMap<&'static str, Vec<Vec<f32>>>,
    stats: PoolStats,
}

impl BufferPool {
    pub fn new(enabled: bool) -> BufferPool {
        BufferPool { enabled, ..Default::default() }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }

    /// Bytes of pooled (free) capacity currently held for `role`.
    pub fn resident_bytes(&self, role: &str) -> usize {
        self.free
            .get(role)
            .map(|l| l.iter().map(|b| b.capacity() * 4).sum())
            .unwrap_or(0)
    }

    /// Fetch a raw buffer for `role` with capacity ≥ `len`, counting
    /// hit/miss/alloc; length and contents are whatever the pooled
    /// buffer held — the `take_*` wrappers shape it.
    fn obtain(&mut self, role: &'static str, len: usize) -> Vec<f32> {
        if !self.enabled {
            self.stats.misses += 1;
            self.stats.alloc_bytes += (len * 4) as u64;
            return Vec::with_capacity(len);
        }
        let list = self.free.entry(role).or_default();
        // best fit: smallest pooled capacity that already covers `len`
        let mut best: Option<(usize, usize)> = None;
        for (i, b) in list.iter().enumerate() {
            if b.capacity() >= len && best.map(|(_, c)| b.capacity() < c).unwrap_or(true) {
                best = Some((i, b.capacity()));
            }
        }
        match best {
            Some((i, _)) => {
                self.stats.hits += 1;
                list.swap_remove(i)
            }
            None => {
                // grow the largest candidate rather than hoarding a new
                // one next to it; count only the capacity delta
                self.stats.misses += 1;
                match (0..list.len()).max_by_key(|&i| list[i].capacity()) {
                    Some(i) => {
                        let mut b = list.swap_remove(i);
                        self.stats.alloc_bytes += ((len - b.capacity()) * 4) as u64;
                        b.reserve(len.saturating_sub(b.len()));
                        b
                    }
                    None => {
                        self.stats.alloc_bytes += (len * 4) as u64;
                        Vec::with_capacity(len)
                    }
                }
            }
        }
    }

    /// A zeroed buffer of exactly `len` floats — for padded containers
    /// whose unwritten tail must read as zero.
    pub fn take_zeroed(&mut self, role: &'static str, len: usize) -> Vec<f32> {
        let mut buf = self.obtain(role, len);
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// A buffer of exactly `len` floats with *arbitrary* contents
    /// (leftovers from its previous pooled life) — for destinations the
    /// caller overwrites completely (packed-row unpack targets).  Skips
    /// `take_zeroed`'s full memset; only capacity growth zero-fills.
    pub fn take_filled(&mut self, role: &'static str, len: usize) -> Vec<f32> {
        let mut buf = self.obtain(role, len);
        if buf.len() > len {
            buf.truncate(len);
        } else {
            buf.resize(len, 0.0);
        }
        buf
    }

    /// An *empty* buffer with capacity for at least `hint` floats —
    /// for staging that is rebuilt with `extend_from_slice`.
    pub fn take_vec(&mut self, role: &'static str, hint: usize) -> Vec<f32> {
        let mut buf = self.obtain(role, hint);
        buf.clear();
        buf.reserve(hint);
        buf
    }

    /// A zeroed tensor of `shape` backed by a pooled buffer.
    pub fn take_tensor(&mut self, role: &'static str, shape: &[usize]) -> Result<TensorF32> {
        let len = shape.iter().product();
        TensorF32::from_vec(shape, self.take_zeroed(role, len))
    }

    /// A tensor of `shape` with arbitrary contents (see
    /// [`BufferPool::take_filled`]) — every element must be written by
    /// the caller before it is read.
    pub fn take_tensor_filled(
        &mut self,
        role: &'static str,
        shape: &[usize],
    ) -> Result<TensorF32> {
        let len = shape.iter().product();
        TensorF32::from_vec(shape, self.take_filled(role, len))
    }

    /// Return a buffer to the role's free list.  When the list is at
    /// capacity, the incoming buffer *replaces the smallest* pooled one
    /// if it is larger (and is dropped otherwise) — so a stream of tiny
    /// returns (e.g. count-round messages reclaimed from the comm
    /// backend into a wire role) can never squat the slots that big
    /// steady-state staging buffers need.
    pub fn give(&mut self, role: &'static str, buf: Vec<f32>) {
        if !self.enabled || buf.capacity() == 0 {
            return;
        }
        let list = self.free.entry(role).or_default();
        if list.len() < MAX_FREE_PER_ROLE {
            list.push(buf);
            return;
        }
        if let Some(i) = (0..list.len()).min_by_key(|&i| list[i].capacity()) {
            if list[i].capacity() < buf.capacity() {
                list[i] = buf;
            }
        }
    }

    /// Return a pooled tensor's backing buffer.
    pub fn give_tensor(&mut self, role: &'static str, t: TensorF32) {
        self.give(role, t.data);
    }

    /// Return a batch of buffers (per-peer staging).
    pub fn give_all(&mut self, role: &'static str, bufs: impl IntoIterator<Item = Vec<f32>>) {
        for b in bufs {
            self.give(role, b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_is_all_hits() {
        let mut p = BufferPool::new(true);
        // warm-up: sizes ratchet the capacity up
        for len in [10usize, 30, 20] {
            let b = p.take_zeroed("t", len);
            assert_eq!(b.len(), len);
            assert!(b.iter().all(|&v| v == 0.0));
            p.give("t", b);
        }
        let warm = p.stats();
        assert!(warm.misses >= 1);
        // steady state: any len ≤ the max seen is a hit, no allocation
        for len in [30usize, 1, 20, 30, 7] {
            let b = p.take_zeroed("t", len);
            assert_eq!(b.len(), len);
            p.give("t", b);
        }
        let d = p.stats().since(&warm);
        assert_eq!(d.misses, 0, "steady state must not allocate");
        assert_eq!(d.hits, 5);
        assert_eq!(d.alloc_bytes, 0);
    }

    #[test]
    fn growth_counts_only_the_delta() {
        let mut p = BufferPool::new(true);
        let b = p.take_zeroed("t", 100);
        let cap = b.capacity();
        p.give("t", b);
        let b = p.take_zeroed("t", cap + 50);
        p.give("t", b);
        let s = p.stats();
        assert_eq!(s.misses, 2);
        // second miss grew the existing buffer: ≤ 50 new floats counted
        assert!(s.alloc_bytes <= ((cap + 50 + 50) * 4) as u64);
    }

    #[test]
    fn roles_are_isolated() {
        let mut p = BufferPool::new(true);
        let b = p.take_zeroed("a", 64);
        p.give("a", b);
        assert!(p.resident_bytes("a") >= 64 * 4);
        assert_eq!(p.resident_bytes("b"), 0);
        // role b cannot see role a's buffer
        let _ = p.take_zeroed("b", 8);
        assert_eq!(p.stats().misses, 2);
    }

    #[test]
    fn reused_buffers_are_rezeroed() {
        let mut p = BufferPool::new(true);
        let mut b = p.take_zeroed("t", 8);
        b.iter_mut().for_each(|v| *v = 7.0);
        p.give("t", b);
        let b = p.take_zeroed("t", 4);
        assert!(b.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn disabled_pool_always_allocates() {
        let mut p = BufferPool::new(false);
        for _ in 0..3 {
            let b = p.take_zeroed("t", 16);
            p.give("t", b);
        }
        assert_eq!(p.stats().hits, 0);
        assert_eq!(p.stats().misses, 3);
        assert_eq!(p.resident_bytes("t"), 0);
    }

    #[test]
    fn take_tensor_shapes_and_recycles() {
        let mut p = BufferPool::new(true);
        let t = p.take_tensor("x", &[2, 3]).unwrap();
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.numel(), 6);
        p.give_tensor("x", t);
        let t = p.take_tensor("x", &[3, 2]).unwrap();
        assert_eq!(t.numel(), 6);
        assert_eq!(p.stats().hits, 1);
    }

    #[test]
    fn take_filled_skips_the_memset_but_has_exact_len() {
        let mut p = BufferPool::new(true);
        let mut b = p.take_zeroed("t", 8);
        b.iter_mut().for_each(|v| *v = 9.0);
        p.give("t", b);
        // shrink: O(1) truncate, stale contents allowed
        let b = p.take_filled("t", 4);
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|&v| v == 9.0), "truncate must not memset");
        p.give("t", b);
        // regrow within capacity: the tail beyond the old len zero-fills
        let b = p.take_filled("t", 8);
        assert_eq!(b.len(), 8);
        assert_eq!(p.stats().misses, 1, "capacity was sufficient throughout");
    }

    #[test]
    fn full_list_evicts_smaller_not_larger() {
        let mut p = BufferPool::new(true);
        // fill the role to capacity with tiny buffers
        for _ in 0..MAX_FREE_PER_ROLE {
            p.give("t", vec![0.0; 4]);
        }
        // a big buffer must displace a tiny one, not be dropped
        p.give("t", vec![0.0; 1000]);
        let b = p.take_zeroed("t", 1000);
        assert_eq!(p.stats().misses, 0, "big buffer was dropped at the door");
        p.give("t", b);
        // and a tiny return cannot evict the big resident
        p.give("t", vec![0.0; 2]);
        let b = p.take_zeroed("t", 1000);
        assert_eq!(p.stats().misses, 0, "tiny return evicted the big buffer");
        drop(b);
    }

    #[test]
    fn take_vec_is_empty_with_capacity() {
        let mut p = BufferPool::new(true);
        let mut b = p.take_vec("s", 32);
        assert!(b.is_empty());
        assert!(b.capacity() >= 32);
        b.extend_from_slice(&[1.0; 32]);
        p.give("s", b);
        let b = p.take_vec("s", 16);
        assert!(b.is_empty());
        assert_eq!(p.stats().hits, 1);
    }
}
