//! Topology-aware process groups — the node-locality layer of the comm
//! substrate.
//!
//! FastMoE's scaling story is "more experts on more GPUs *across
//! multiple nodes*", but a flat [`Comm`] ring treats every peer as
//! equidistant.  This module adds the missing abstraction in three
//! pieces:
//!
//! * [`Topology`] — the static rank → (node, local rank) mapping, from
//!   the `[comm] nodes` / `local_size` config (node blocks are
//!   contiguous: rank `r` lives on node `r / local_size`; the lowest
//!   rank of a node is its *leader*).  `Topology::flat(w)` — one rank
//!   per node — is the default and degenerates every policy below to
//!   today's behaviour bit-for-bit.
//! * [`ProcessGroup`] / [`BoundGroup`] — a sub-group handle over a
//!   subset of world ranks with its **own rank/size/tag namespace**.
//!   [`Comm::split`] builds the `{intra, inter}` pair for a topology;
//!   [`ProcessGroup::bind`] borrows the world handle and yields a
//!   [`BoundGroup`] that *implements [`Comm`]*, so every collective of
//!   the trait (`all_to_all_v`, `all_reduce_sum`, `all_reduce_start`,
//!   barriers, …) runs identically on the world group or any
//!   sub-group — the seam the hierarchical policies are ~100 lines on
//!   top of, instead of bespoke forks of every collective.
//! * [`TopoComm`] — a transparent wrapper selecting the collective
//!   *policy* (`[comm] topology = "flat" | "hier"`).  Flat is a pure
//!   pass-through.  Hier reroutes:
//!   * **all-to-all** (HetuMoE-style): members hand their
//!     per-destination-*node* aggregates to the node leader, leaders
//!     run ONE inter-node exchange (an ordinary `all_to_all_v` on the
//!     inter sub-group), and leaders scatter arrivals to their
//!     members — `n-1` per-rank wire messages become `nodes-1` leader
//!     messages, and the intra share never touches the inter link.
//!     Byte routing is exact, so results are **element-identical** to
//!     the flat collective.
//!   * **all-reduce** (two-level tree): intra-node reduce onto the
//!     leader (member buffers added in ascending local-rank order),
//!     one ring all-reduce over the leaders, intra-node broadcast —
//!     the alternate ring builder under
//!     [`PendingAllReduce`](super::PendingAllReduce), so the trainers'
//!     bucketed overlapped `GradSync` composes with it for free.  The
//!     reduction order is *fixed and documented* (members ascending,
//!     then the leader ring's chunk order) and identical between the
//!     blocking and bucketed paths, so hier-blocking == hier-bucketed
//!     bitwise; it differs from the flat ring's order, so hier vs flat
//!     agree exactly only where f32 addition happens to be associative
//!     (the conformance matrix pins both properties).
//!
//! Namespace note: a [`ProcessGroup`]'s tags are salted into a band of
//! the tag space and sequenced by its own counter, so concurrent intra
//! groups on different nodes (disjoint members) and the world group
//! never collide.  Two *separate* `ProcessGroup` instances over the
//! same members (e.g. two `Comm::split` calls) restart the sequence:
//! safe once the first group's collectives have fully drained, but
//! do not interleave their in-flight collectives — hold one
//! [`CommGroups`] per comm lifetime, as [`TopoComm`] does.

use super::{all_reduce_start_hier, Comm, CommRequest, PendingA2a, PendingAllReduce};
use crate::error::{Error, Result};
use crate::metrics::Counters;

/// Tag-space band of intra-node (same-node members) groups.
const SALT_INTRA: u64 = 1 << 62;
/// Tag-space band of the inter-node (leaders) group.
const SALT_INTER: u64 = 1 << 61;

/// Process-wide count of [`Topology::from_hosts`] flat fallbacks — a
/// hosts list that *looked* multi-node but didn't satisfy the
/// contiguous-uniform-runs invariant silently loses all locality
/// routing, which operators should notice (see [`topology_fallbacks`]).
static TOPOLOGY_FALLBACKS: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(0);

/// How many times [`Topology::from_hosts`] has fallen back to a flat
/// topology this process (each fallback also logs a one-line warning
/// with the offending pattern).
pub fn topology_fallbacks() -> u64 {
    TOPOLOGY_FALLBACKS.load(std::sync::atomic::Ordering::Relaxed)
}

/// Static node topology of a world of ranks: `world` ranks in
/// contiguous blocks of `local_size` per node.  Rank `r` is local rank
/// `r % local_size` on node `r / local_size`; local rank 0 is the
/// node's *leader*.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Topology {
    world: usize,
    local_size: usize,
}

impl Topology {
    /// One rank per node — the flat (seed) topology every policy
    /// degenerates to.
    pub fn flat(world: usize) -> Topology {
        Topology { world: world.max(1), local_size: 1 }
    }

    /// `world` ranks in nodes of `local_size`; `world` must be a
    /// positive multiple of `local_size`.
    pub fn new(world: usize, local_size: usize) -> Result<Topology> {
        if world == 0 || local_size == 0 || world % local_size != 0 {
            return Err(Error::Config(format!(
                "topology: {world} ranks not divisible into nodes of {local_size}"
            )));
        }
        Ok(Topology { world, local_size })
    }

    /// [`Topology::new`] from a node count instead of a node size.
    pub fn from_nodes(world: usize, nodes: usize) -> Result<Topology> {
        if nodes == 0 || world % nodes != 0 {
            return Err(Error::Config(format!(
                "topology: {world} ranks not divisible into {nodes} nodes"
            )));
        }
        Topology::new(world, world / nodes)
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn local_size(&self) -> usize {
        self.local_size
    }

    pub fn nodes(&self) -> usize {
        self.world / self.local_size
    }

    /// Whether any node holds more than one rank — the gate every
    /// hierarchical policy checks before departing from flat.
    pub fn hierarchical(&self) -> bool {
        self.local_size > 1
    }

    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.local_size
    }

    pub fn local_of(&self, rank: usize) -> usize {
        rank % self.local_size
    }

    /// World rank of node `t`'s leader (its lowest rank).
    pub fn leader_of(&self, node: usize) -> usize {
        node * self.local_size
    }

    pub fn is_leader(&self, rank: usize) -> bool {
        self.local_of(rank) == 0
    }

    /// World ranks of node `t`, ascending.
    pub fn node_ranks(&self, node: usize) -> std::ops::Range<usize> {
        node * self.local_size..(node + 1) * self.local_size
    }

    /// World ranks of every node leader, ascending.
    pub fn leaders(&self) -> Vec<usize> {
        (0..self.nodes()).map(|t| self.leader_of(t)).collect()
    }

    /// Discover the topology from a multi-host `hosts` list (one
    /// `addr[:port]` entry per rank, the `TcpGroup::connect` layout):
    /// ranks whose entries share an address share a node.
    ///
    /// The contiguous-block invariant of [`Topology`] still applies, so
    /// discovery succeeds only when same-address ranks form contiguous
    /// runs of one uniform length — the natural way a hosts list is
    /// written (`a,a,b,b`).  Anything else (ragged runs, an address
    /// reappearing later, a single host) degrades to [`Topology::flat`]
    /// rather than erroring: flat is always correct, just not
    /// locality-aware.  Every non-trivial fallback logs one warning
    /// naming the offending pattern and bumps the process-wide
    /// [`topology_fallbacks`] counter, so a mis-ordered hosts list
    /// can't silently cost the hierarchical routing.
    pub fn from_hosts(hosts: &[String]) -> Result<Topology> {
        if hosts.is_empty() {
            return Err(Error::Config("topology: empty hosts list".into()));
        }
        let addr = |h: &String| -> String {
            // strip an optional `:port`; bracketed IPv6 keeps its brackets
            match h.rfind(':') {
                Some(i) if !h[i + 1..].contains(']') => h[..i].to_string(),
                _ => h.clone(),
            }
        };
        let fallback = |why: &str| -> Topology {
            TOPOLOGY_FALLBACKS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            eprintln!(
                "warning: topology discovery fell back to flat ({why}) — \
                 hosts list [{}] loses hierarchical routing",
                hosts.join(", ")
            );
            Topology::flat(hosts.len())
        };
        // contiguous same-address runs, checking no address reappears
        let mut runs: Vec<(String, usize)> = Vec::new();
        for h in hosts {
            let a = addr(h);
            match runs.last_mut() {
                Some((last, n)) if *last == a => *n += 1,
                _ => {
                    if runs.iter().any(|(seen, _)| *seen == a) {
                        return Ok(fallback(&format!(
                            "address {a} reappears non-contiguously"
                        )));
                    }
                    runs.push((a, 1));
                }
            }
        }
        let local = runs[0].1;
        if runs.len() < 2 {
            // a single distinct address is the *expected* one-node
            // layout, not a malformed multi-node list: flat quietly
            return Ok(Topology::flat(hosts.len()));
        }
        if runs.iter().any(|(_, n)| *n != local) {
            let shape: Vec<String> =
                runs.iter().map(|(a, n)| format!("{a}×{n}")).collect();
            return Ok(fallback(&format!(
                "ragged node runs {}",
                shape.join(", ")
            )));
        }
        Topology::new(hosts.len(), local)
    }
}

/// A sub-group of world ranks with its own rank/size/tag namespace —
/// the persistent half of the [`Comm::split`] result.  Bind it to the
/// world handle ([`ProcessGroup::bind`]) to get a [`BoundGroup`] that
/// implements [`Comm`]; the sequence counter lives here so tag
/// allocation survives across binds.
#[derive(Debug)]
pub struct ProcessGroup {
    /// Member world ranks in group-rank order (ascending).
    ranks: Vec<usize>,
    /// This rank's index in `ranks`.
    my: usize,
    /// Tag-space band of this group's collectives.
    salt: u64,
    /// The group's own collective sequence counter.
    seq: u64,
}

impl ProcessGroup {
    /// Build a group over `ranks` (must contain `me`); `salt` selects
    /// the tag band (must be disjoint from the world band and from any
    /// concurrently-active group sharing a member).
    pub fn new(ranks: Vec<usize>, me: usize, salt: u64) -> Result<ProcessGroup> {
        let my = ranks
            .iter()
            .position(|&r| r == me)
            .ok_or_else(|| Error::Comm(format!("rank {me} not in group {ranks:?}")))?;
        Ok(ProcessGroup { ranks, my, salt, seq: 0 })
    }

    /// Member world ranks, group-rank order.
    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    /// This rank's group rank.
    pub fn rank(&self) -> usize {
        self.my
    }

    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    /// Borrow the world handle and expose this group as a [`Comm`]:
    /// group ranks translate to world ranks, tags into the group's
    /// salted band, everything else (wire, parking, arrival order,
    /// pools, counters) is the backend's.
    pub fn bind<'a, C: Comm + ?Sized>(&'a mut self, comm: &'a mut C) -> BoundGroup<'a, C> {
        BoundGroup { pg: self, comm }
    }
}

/// A [`ProcessGroup`] bound to the world handle — the view that
/// implements [`Comm`], so every collective of the trait runs on the
/// sub-group unchanged.
pub struct BoundGroup<'a, C: Comm + ?Sized> {
    pg: &'a mut ProcessGroup,
    comm: &'a mut C,
}

impl<C: Comm + ?Sized> BoundGroup<'_, C> {
    fn world(&self, p: usize) -> Result<usize> {
        self.pg
            .ranks
            .get(p)
            .copied()
            .ok_or_else(|| Error::Comm(format!("group peer {p} of {}", self.pg.size())))
    }

    fn tag(&self, tag: u64) -> u64 {
        self.pg.salt | tag
    }
}

impl<C: Comm + ?Sized> Comm for BoundGroup<'_, C> {
    fn rank(&self) -> usize {
        self.pg.my
    }

    fn size(&self) -> usize {
        self.pg.ranks.len()
    }

    fn counters(&mut self) -> &mut Counters {
        self.comm.counters()
    }

    fn send(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> Result<()> {
        let dst = self.world(dst)?;
        let tag = self.tag(tag);
        self.comm.send(dst, tag, data)
    }

    fn recv(&mut self, src: usize, tag: u64) -> Result<Vec<f32>> {
        let src = self.world(src)?;
        let tag = self.tag(tag);
        self.comm.recv(src, tag)
    }

    fn next_seq(&mut self) -> u64 {
        self.pg.seq += 1;
        self.pg.seq
    }

    fn isend(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> Result<CommRequest> {
        let dst = self.world(dst)?;
        let tag = self.tag(tag);
        self.comm.isend(dst, tag, data)
    }

    /// Requests carry *world* coordinates, so `wait`/`wait_all` can
    /// delegate to the backend (and inherit its arrival-order
    /// completion) without translation.
    fn irecv(&mut self, src: usize, tag: u64) -> Result<CommRequest> {
        let src = self.world(src)?;
        let tag = self.tag(tag);
        self.comm.irecv(src, tag)
    }

    fn wait(&mut self, req: CommRequest) -> Result<Option<Vec<f32>>> {
        self.comm.wait(req)
    }

    fn wait_all(&mut self, reqs: Vec<CommRequest>) -> Result<Vec<Option<Vec<f32>>>> {
        self.comm.wait_all(reqs)
    }

    fn flush(&mut self) -> Result<()> {
        self.comm.flush()
    }

    fn reclaim_spent(&mut self) -> Vec<Vec<f32>> {
        self.comm.reclaim_spent()
    }

    fn recycle(&mut self, bufs: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        self.comm.recycle(bufs)
    }

    // barrier() intentionally NOT overridden: the trait's dissemination
    // default runs over the group's own send/recv translation, which is
    // exactly the sub-group barrier (the backend's world barrier would
    // wait on non-members).
}

/// The `{intra, inter}` pair of one topology split: the intra-node
/// group every rank belongs to, and the leaders' inter-node group
/// (`None` on non-leaders).
#[derive(Debug)]
pub struct CommGroups {
    pub intra: ProcessGroup,
    pub inter: Option<ProcessGroup>,
}

impl CommGroups {
    /// Build both groups for `rank` under `topo` (pure rank math).
    pub fn new(topo: &Topology, rank: usize) -> Result<CommGroups> {
        let node = topo.node_of(rank);
        let intra =
            ProcessGroup::new(topo.node_ranks(node).collect(), rank, SALT_INTRA)?;
        let inter = if topo.is_leader(rank) {
            Some(ProcessGroup::new(topo.leaders(), rank, SALT_INTER)?)
        } else {
            None
        };
        Ok(CommGroups { intra, inter })
    }
}

/// Policy-selecting wrapper: a [`Comm`] whose collectives route
/// according to a [`Topology`].  Flat topologies delegate everything —
/// bit-for-bit today's behaviour; hierarchical topologies reroute
/// `all_to_all_v_start` (and therefore `all_to_all_v`, `all_gather`,
/// `barrier_a2a`) through the node leaders and build two-level rings
/// under `all_reduce_sum` / `all_reduce_start`.  Transport-level calls
/// (`send`/`recv`/`isend`/`irecv`/`wait*`/`flush`/pools/barrier) always
/// delegate, so the layer's chunked pipelines run unchanged on top.
pub struct TopoComm<C: Comm> {
    inner: C,
    topo: Topology,
    /// Persistent sub-group namespaces (`None` when flat).
    groups: Option<CommGroups>,
}

impl<C: Comm> TopoComm<C> {
    /// Wrap `inner` under `topo`; `topo.world()` must match the
    /// handle's size.
    pub fn new(inner: C, topo: Topology) -> Result<TopoComm<C>> {
        if topo.world() != inner.size() {
            return Err(Error::Comm(format!(
                "topology is over {} ranks, comm has {}",
                topo.world(),
                inner.size()
            )));
        }
        let groups = if topo.hierarchical() {
            Some(CommGroups::new(&topo, inner.rank())?)
        } else {
            None
        };
        Ok(TopoComm { inner, topo, groups })
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// The wrapped backend handle (e.g. for backend-specific stats).
    pub fn inner(&self) -> &C {
        &self.inner
    }

    pub fn into_inner(self) -> C {
        self.inner
    }

    /// The hierarchical all-to-all — the three-hop payload route:
    /// member → leader aggregation, ONE leader exchange on the inter
    /// group, leader → member scatter.  There is **no flat count
    /// round**: every hop is self-describing (aggregates carry inline
    /// length headers, the scatter carries per-source lengths), so a
    /// rank's wire traffic really is one local message up, one local
    /// message down, and — on leaders — `nodes − 1` inter-node
    /// messages, which is the α-term shrinkage
    /// [`crate::sim::NetModel::all_to_all_hier`] prices.  Exact byte
    /// routing: every `send[q][d]` arrives at `d` intact and in
    /// ascending-source order, so results are element-identical to the
    /// flat collective.  Completes before returning (the pipelined
    /// layer path overlaps via its chunk schedule instead), handing
    /// back a pre-filled [`PendingA2a`].
    ///
    /// Counter note: the leaders' inner exchange is an ordinary
    /// sub-group collective and records its own `a2a_*` counters on
    /// top of this call's — a leader handle therefore logs two
    /// `a2a_calls` per hier exchange.  `a2a_hier_calls` marks the
    /// logical collective once per rank; the flat-vs-hier wire
    /// accounting the benches consume lives in the layer's
    /// `moe_a2a_bytes` and the backend's `bytes_sent`, not here.
    fn a2a_start_hier(&mut self, send: Vec<Vec<f32>>) -> Result<PendingA2a> {
        let w = self.inner.size();
        let rank = self.inner.rank();
        if send.len() != w {
            return Err(Error::Comm(format!(
                "all_to_all_v: {} buffers for {} peers",
                send.len(),
                w
            )));
        }
        let topo = self.topo;
        let l_sz = topo.local_size();
        let nodes = topo.nodes();
        let my_local = topo.local_of(rank);
        self.inner.counters().add("a2a_calls", 1);
        self.inner.counters().add("a2a_hier_calls", 1);

        // ---- per-destination-node aggregates → leader ----
        // A[t] = [len(send[d]) per d ∈ node t] ++ payloads; the member
        // message prefixes each A[t] with its total length.
        let mut msg: Vec<f32> = Vec::with_capacity(
            nodes + nodes * l_sz + send.iter().map(|b| b.len()).sum::<usize>(),
        );
        for t in 0..nodes {
            let total: usize =
                topo.node_ranks(t).map(|d| send[d].len()).sum::<usize>() + l_sz;
            // lengths ride the wire as f32 (the base protocol's count
            // convention); a node *aggregate* sums local_size payloads
            // and can hit the 2^24 exact-integer ceiling first — fail
            // loudly instead of splicing a rounded offset
            if total >= (1 << 24) {
                return Err(Error::Comm(format!(
                    "hier a2a: node {t} aggregate of {total} floats exceeds \
                     the f32-exact length limit (2^24); shrink the batch or \
                     use topology = \"flat\""
                )));
            }
            msg.push(total as f32);
        }
        for t in 0..nodes {
            for d in topo.node_ranks(t) {
                msg.push(send[d].len() as f32);
            }
            for d in topo.node_ranks(t) {
                msg.extend_from_slice(&send[d]);
            }
        }
        drop(send);
        self.inner
            .counters()
            .add("a2a_data_bytes", (msg.len() * 4) as u64);
        let groups = self.groups.as_mut().expect("hier topology has groups");
        let (gtag, stag) = {
            let mut intra = groups.intra.bind(&mut self.inner);
            let iseq = intra.next_seq();
            let gtag = (iseq << 8) | 1;
            let stag = (iseq << 8) | 2;
            intra.isend(0, gtag, msg)?;
            (gtag, stag)
        };

        // ---- phase 2b (leaders): assemble, exchange, scatter ----
        if my_local == 0 {
            // gather members ascending (self loops back through the
            // backend's parking) and splice their aggregates per node
            let mut b_out: Vec<Vec<f32>> = (0..nodes).map(|_| Vec::new()).collect();
            {
                let mut intra = groups.intra.bind(&mut self.inner);
                for l in 0..l_sz {
                    let m = intra.recv(l, gtag)?;
                    if m.len() < nodes {
                        return Err(Error::Comm(format!(
                            "hier a2a: member {l} aggregate too short ({})",
                            m.len()
                        )));
                    }
                    let mut off = nodes;
                    for (t, out) in b_out.iter_mut().enumerate() {
                        let alen = m[t] as usize;
                        if off + alen > m.len() {
                            return Err(Error::Comm(format!(
                                "hier a2a: member {l} aggregate for node {t} \
                                 overruns its message"
                            )));
                        }
                        out.extend_from_slice(&m[off..off + alen]);
                        off += alen;
                    }
                    if off != m.len() {
                        return Err(Error::Comm(format!(
                            "hier a2a: member {l} aggregate has {} trailing floats",
                            m.len() - off
                        )));
                    }
                    // consumed: back to the backend's receive freelist
                    // (keeps the FramePool hand-out/return balance flat)
                    let _ = intra.recycle(vec![m]);
                }
            }
            // the assembled per-node buffers ride the base protocol's
            // f32 count phase — guard their lengths like the member
            // aggregates above (a leader concatenates local_size of
            // them, so it hits the ceiling first)
            for (t, b) in b_out.iter().enumerate() {
                if b.len() >= (1 << 24) {
                    return Err(Error::Comm(format!(
                        "hier a2a: assembled exchange for node {t} is {} floats, \
                         past the f32-exact length limit (2^24); shrink the \
                         batch or use topology = \"flat\"",
                        b.len()
                    )));
                }
            }
            // ONE inter-node exchange — an ordinary collective on the
            // leaders' sub-group (the ProcessGroup seam at work)
            let b_in = {
                let inter = groups.inter.as_mut().expect("leader has inter group");
                inter.bind(&mut self.inner).all_to_all_v(b_out)?
            };
            // scatter: C[d] = [len(send[q][d]) per source q, ascending]
            // ++ payloads in the same order (node-major · local-minor
            // == world order) — self-describing, so members need no
            // separate count round
            let mut c_hdr: Vec<Vec<f32>> =
                (0..l_sz).map(|_| Vec::with_capacity(w)).collect();
            let mut c_out: Vec<Vec<f32>> = (0..l_sz).map(|_| Vec::new()).collect();
            for (s, bs) in b_in.iter().enumerate() {
                let mut off = 0usize;
                for l in 0..l_sz {
                    if off + l_sz > bs.len() {
                        return Err(Error::Comm(format!(
                            "hier a2a: node {s} member {l} header overruns"
                        )));
                    }
                    let lens: Vec<usize> =
                        bs[off..off + l_sz].iter().map(|&x| x as usize).collect();
                    off += l_sz;
                    for (d, out) in c_out.iter_mut().enumerate() {
                        if off + lens[d] > bs.len() {
                            return Err(Error::Comm(format!(
                                "hier a2a: node {s} member {l} payload for \
                                 local {d} overruns"
                            )));
                        }
                        c_hdr[d].push(lens[d] as f32);
                        out.extend_from_slice(&bs[off..off + lens[d]]);
                        off += lens[d];
                    }
                }
                if off != bs.len() {
                    return Err(Error::Comm(format!(
                        "hier a2a: node {s} buffer has {} trailing floats",
                        bs.len() - off
                    )));
                }
            }
            // exchange buffers consumed: feed the receive freelist
            let _ = self.inner.recycle(b_in);
            let mut intra = groups.intra.bind(&mut self.inner);
            for (d, (mut hdr, body)) in
                c_hdr.into_iter().zip(c_out).enumerate()
            {
                if hdr.len() != w {
                    return Err(Error::Comm(format!(
                        "hier a2a: scatter for local {d} saw {} sources, \
                         world is {w}",
                        hdr.len()
                    )));
                }
                hdr.extend(body);
                intra.isend(d, stag, hdr)?;
            }
        }

        // ---- everyone: receive the scatter, split by its header ----
        let c = groups.intra.bind(&mut self.inner).recv(0, stag)?;
        if c.len() < w {
            return Err(Error::Comm(format!(
                "hier a2a: scatter for rank {rank} too short ({} floats)",
                c.len()
            )));
        }
        let expected: Vec<usize> = c[..w].iter().map(|&x| x as usize).collect();
        let total: usize = expected.iter().sum();
        if c.len() != w + total {
            return Err(Error::Comm(format!(
                "hier a2a: scatter for rank {rank} has {} payload floats, \
                 header says {total}",
                c.len() - w
            )));
        }
        let mut bufs: Vec<Option<Vec<f32>>> = (0..w).map(|_| None).collect();
        let mut off = w;
        for (q, slot) in bufs.iter_mut().enumerate() {
            let n = expected[q];
            *slot = Some(c[off..off + n].to_vec());
            off += n;
        }
        let _ = self.inner.recycle(vec![c]);
        Ok(PendingA2a {
            reqs: (0..w).map(|_| None).collect(),
            bufs,
            expected,
        })
    }
}

impl<C: Comm> Comm for TopoComm<C> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn counters(&mut self) -> &mut Counters {
        self.inner.counters()
    }

    fn send(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> Result<()> {
        self.inner.send(dst, tag, data)
    }

    fn recv(&mut self, src: usize, tag: u64) -> Result<Vec<f32>> {
        self.inner.recv(src, tag)
    }

    fn next_seq(&mut self) -> u64 {
        self.inner.next_seq()
    }

    fn isend(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> Result<CommRequest> {
        self.inner.isend(dst, tag, data)
    }

    fn irecv(&mut self, src: usize, tag: u64) -> Result<CommRequest> {
        self.inner.irecv(src, tag)
    }

    fn wait(&mut self, req: CommRequest) -> Result<Option<Vec<f32>>> {
        self.inner.wait(req)
    }

    fn wait_all(&mut self, reqs: Vec<CommRequest>) -> Result<Vec<Option<Vec<f32>>>> {
        self.inner.wait_all(reqs)
    }

    fn flush(&mut self) -> Result<()> {
        self.inner.flush()
    }

    fn reclaim_spent(&mut self) -> Vec<Vec<f32>> {
        self.inner.reclaim_spent()
    }

    fn recycle(&mut self, bufs: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        self.inner.recycle(bufs)
    }

    /// The backend's barrier (e.g. the thread handles' OS barrier).
    fn barrier(&mut self) -> Result<()> {
        self.inner.barrier()
    }

    fn all_to_all_v_start(&mut self, send: Vec<Vec<f32>>) -> Result<PendingA2a> {
        if self.topo.hierarchical() && self.inner.size() > 1 {
            self.a2a_start_hier(send)
        } else {
            self.inner.all_to_all_v_start(send)
        }
    }

    /// Hier: the two-level tree as `all_reduce_start` completed on the
    /// spot, so blocking and bucketed results are bitwise-identical by
    /// construction (one code path).  Costs one staging copy in and
    /// one out versus the flat in-place ring — the documented price of
    /// sharing the schedule; hot paths use the bucketed form, whose
    /// buffers recycle through the backend freelist.
    fn all_reduce_sum(&mut self, buf: &mut [f32]) -> Result<()> {
        if !self.topo.hierarchical() || self.inner.size() <= 1 {
            return self.inner.all_reduce_sum(buf);
        }
        let pending = self.all_reduce_start(vec![buf.to_vec()])?;
        let out = pending.finish(self)?.pop().expect("one bucket");
        buf.copy_from_slice(&out);
        let _ = self.inner.recycle(vec![out]);
        Ok(())
    }

    fn all_reduce_start(&mut self, bufs: Vec<Vec<f32>>) -> Result<PendingAllReduce> {
        if self.topo.hierarchical() && self.inner.size() > 1 {
            let topo = self.topo;
            all_reduce_start_hier(self, &topo, bufs)
        } else {
            self.inner.all_reduce_start(bufs)
        }
    }

    /// Rail-aware ZeRO schedule: under a hierarchical topology each
    /// local rank aggregates its slice within the node and rings across
    /// nodes with its peer rank (same local index), so every NIC
    /// carries inter-node traffic instead of the tree's leader alone.
    /// Flat topologies fall through to the inner (plain-ring) schedule.
    fn all_reduce_zero(&mut self, bufs: Vec<Vec<f32>>) -> Result<PendingAllReduce> {
        if self.topo.hierarchical() && self.inner.size() > 1 {
            let topo = self.topo;
            crate::comm::all_reduce_zero_start(self, &topo, bufs)
        } else {
            self.inner.all_reduce_zero(bufs)
        }
    }

    fn zero_shard(&self, len: usize) -> std::ops::Range<usize> {
        if self.topo.hierarchical() && self.inner.size() > 1 {
            crate::comm::zero_shard_range(&self.topo, self.inner.rank(), len)
        } else {
            self.inner.zero_shard(len)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_workers;

    #[test]
    fn topology_mapping_and_validation() {
        let t = Topology::new(8, 2).unwrap();
        assert_eq!(t.nodes(), 4);
        assert!(t.hierarchical());
        assert_eq!(t.node_of(5), 2);
        assert_eq!(t.local_of(5), 1);
        assert_eq!(t.leader_of(2), 4);
        assert!(t.is_leader(4) && !t.is_leader(5));
        assert_eq!(t.node_ranks(1).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(t.leaders(), vec![0, 2, 4, 6]);
        assert_eq!(Topology::from_nodes(8, 2).unwrap().local_size(), 4);
        assert!(!Topology::flat(8).hierarchical());
        assert!(Topology::new(8, 3).is_err());
        assert!(Topology::new(0, 1).is_err());
        assert!(Topology::from_nodes(8, 3).is_err());
    }

    #[test]
    fn topology_discovery_from_hosts() {
        let hosts = |list: &[&str]| -> Vec<String> {
            list.iter().map(|s| s.to_string()).collect()
        };
        // the natural multi-host layout: contiguous uniform runs
        let t = Topology::from_hosts(&hosts(&[
            "10.0.0.1:5000",
            "10.0.0.1:5001",
            "10.0.0.2:5000",
            "10.0.0.2:5001",
        ]))
        .unwrap();
        assert_eq!((t.nodes(), t.local_size()), (2, 2));
        // port-less entries group the same way
        let t = Topology::from_hosts(&hosts(&["a", "a", "a", "b", "b", "b"])).unwrap();
        assert_eq!((t.nodes(), t.local_size()), (2, 3));
        // one host only → nothing to discover → flat, and *not* a
        // fallback (single-node is the expected layout, no warning)
        let c0 = topology_fallbacks();
        let t = Topology::from_hosts(&hosts(&["127.0.0.1:1", "127.0.0.1:2"])).unwrap();
        assert!(!t.hierarchical());
        assert_eq!(topology_fallbacks(), c0, "single host must not warn");
        // ragged runs violate the contiguous-block invariant → flat,
        // counted (mixed host list: a×2 then b×1)
        let t = Topology::from_hosts(&hosts(&["a:1", "a:2", "b:1"])).unwrap();
        assert!(!t.hierarchical());
        assert_eq!(t.world(), 3);
        // an address reappearing non-contiguously → flat, counted
        let t = Topology::from_hosts(&hosts(&["a:1", "b:1", "a:2", "b:2"])).unwrap();
        assert!(!t.hierarchical());
        // both degradations above surfaced on the counter (≥, not ==:
        // other tests in the binary may also trip fallbacks in parallel)
        assert!(
            topology_fallbacks() >= c0 + 2,
            "expected ≥ {} fallbacks, saw {}",
            c0 + 2,
            topology_fallbacks()
        );
        // empty list is a config error
        assert!(Topology::from_hosts(&[]).is_err());
    }

    #[test]
    fn split_builds_intra_and_inter_groups() {
        run_workers(4, |h| {
            let topo = Topology::new(4, 2).unwrap();
            let g = h.split(&topo)?;
            let node = h.rank() / 2;
            assert_eq!(g.intra.ranks(), &[node * 2, node * 2 + 1]);
            assert_eq!(g.intra.rank(), h.rank() % 2);
            match (h.rank() % 2, &g.inter) {
                (0, Some(inter)) => {
                    assert_eq!(inter.ranks(), &[0, 2]);
                    assert_eq!(inter.rank(), node);
                }
                (_, None) => {}
                other => panic!("bad inter group for rank {}: {other:?}", h.rank()),
            }
            // size mismatch is rejected
            assert!(h.split(&Topology::new(8, 2).unwrap()).is_err());
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn subgroup_collectives_run_unchanged() {
        // The seam claim itself: the *same* trait collectives run on a
        // sub-group — a2a within the node, all-reduce across leaders.
        run_workers(4, |mut h| {
            let topo = Topology::new(4, 2).unwrap();
            let mut g = h.split(&topo)?;
            let r = h.rank();
            {
                let mut intra = g.intra.bind(&mut h);
                assert_eq!(intra.size(), 2);
                let send: Vec<Vec<f32>> =
                    (0..2).map(|p| vec![(r * 10 + p) as f32; p + 1]).collect();
                let recv = intra.all_to_all_v(send)?;
                let node = topo.node_of(r);
                for (p, buf) in recv.iter().enumerate() {
                    let peer = topo.node_ranks(node).nth(p).unwrap();
                    assert_eq!(
                        buf,
                        &vec![(peer * 10 + topo.local_of(r)) as f32; topo.local_of(r) + 1]
                    );
                }
                intra.barrier()?;
            }
            if let Some(inter) = g.inter.as_mut() {
                let mut inter = inter.bind(&mut h);
                let mut buf = vec![(r + 1) as f32; 5];
                inter.all_reduce_sum(&mut buf)?;
                // leaders are 0 and 2: 1 + 3
                assert!(buf.iter().all(|&x| x == 4.0), "{buf:?}");
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn subgroup_nonblocking_requests_roundtrip() {
        run_workers(4, |mut h| {
            let topo = Topology::new(4, 2).unwrap();
            let mut g = h.split(&topo)?;
            let mut intra = g.intra.bind(&mut h);
            let me = intra.rank();
            let other = 1 - me;
            let tag = (intra.next_seq() << 8) | 1;
            intra.isend(other, tag, vec![me as f32; 3])?;
            let req = intra.irecv(other, tag)?;
            let data = intra.wait(req)?.unwrap();
            assert_eq!(data, vec![other as f32; 3]);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn flat_topo_comm_is_pure_passthrough() {
        run_workers(3, |h| {
            let topo = Topology::flat(3);
            let mut c = TopoComm::new(h, topo)?;
            let r = c.rank() as f32;
            let send: Vec<Vec<f32>> = (0..3).map(|p| vec![r, p as f32]).collect();
            let recv = c.all_to_all_v(send)?;
            for (p, buf) in recv.iter().enumerate() {
                assert_eq!(buf, &vec![p as f32, r]);
            }
            let mut buf = vec![r + 1.0; 4];
            c.all_reduce_sum(&mut buf)?;
            assert!(buf.iter().all(|&x| x == 6.0));
            assert_eq!(c.counters().get("a2a_hier_calls"), 0);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn hier_a2a_is_element_identical_to_flat() {
        for (w, l) in [(4usize, 2usize), (6, 3), (4, 4), (8, 2)] {
            run_workers(w, move |h| {
                let r = h.rank();
                // ragged payloads incl. empties
                let send: Vec<Vec<f32>> = (0..w)
                    .map(|p| {
                        (0..(r * 3 + p * 5) % 7)
                            .map(|i| (r * 1000 + p * 10 + i) as f32)
                            .collect()
                    })
                    .collect();
                let mut c = TopoComm::new(h, Topology::new(w, l).unwrap())?;
                let recv = c.all_to_all_v(send)?;
                for (p, buf) in recv.iter().enumerate() {
                    let want: Vec<f32> = (0..(p * 3 + r * 5) % 7)
                        .map(|i| (p * 1000 + r * 10 + i) as f32)
                        .collect();
                    assert_eq!(buf, &want, "w={w} l={l}: rank {r} from peer {p}");
                }
                assert!(c.counters().get("a2a_hier_calls") > 0);
                Ok(())
            })
            .unwrap();
        }
    }

    #[test]
    fn hier_a2a_start_prefills_pending() {
        run_workers(4, |h| {
            let r = h.rank();
            let send: Vec<Vec<f32>> =
                (0..4).map(|p| vec![(r * 4 + p) as f32; p + 1]).collect();
            let mut c = TopoComm::new(h, Topology::new(4, 2).unwrap())?;
            let mut pending = c.all_to_all_v_start(send)?;
            for p in (0..4).rev() {
                assert_eq!(pending.expected(p), r + 1);
                let buf = pending.wait_peer(&mut c, p)?;
                assert_eq!(buf, vec![(p * 4 + r) as f32; r + 1]);
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn hier_all_reduce_sums_exactly_on_integer_data() {
        // integer-valued f32s: addition is associative, so hier (whose
        // documented reduction order differs from the flat ring) must
        // match the flat result bitwise
        for (w, l) in [(4usize, 2usize), (6, 3), (4, 4), (8, 4)] {
            run_workers(w, move |mut h| {
                let r = h.rank();
                let mut flat: Vec<f32> =
                    (0..37).map(|i| (r * 100 + i) as f32).collect();
                h.all_reduce_sum(&mut flat)?;
                let mut c = TopoComm::new(h, Topology::new(w, l).unwrap())?;
                let mut buf: Vec<f32> = (0..37).map(|i| (r * 100 + i) as f32).collect();
                c.all_reduce_sum(&mut buf)?;
                assert_eq!(buf, flat, "w={w} l={l}");
                Ok(())
            })
            .unwrap();
        }
    }

    #[test]
    fn hier_bucketed_matches_hier_blocking_bitwise() {
        run_workers(4, |h| {
            let r = h.rank();
            let mut c = TopoComm::new(h, Topology::new(4, 2).unwrap())?;
            // order-sensitive values: pins one shared reduction order
            let lens = [0usize, 7, 64, 129, 3];
            let bufs: Vec<Vec<f32>> = lens
                .iter()
                .enumerate()
                .map(|(b, &n)| {
                    (0..n)
                        .map(|i| (r + 1) as f32 * 1.1 + b as f32 * 0.3 + i as f32 * 0.013)
                        .collect()
                })
                .collect();
            let mut want = bufs.clone();
            for wbuf in want.iter_mut() {
                c.all_reduce_sum(wbuf)?;
            }
            let got = c.all_reduce_start(bufs.clone())?.finish(&mut c)?;
            assert_eq!(got, want, "finish != hier blocking");
            let mut pending = c.all_reduce_start(bufs)?;
            for b in (0..lens.len()).rev() {
                assert_eq!(pending.wait_bucket(&mut c, b)?, want[b], "bucket {b}");
            }
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn hier_all_reduce_single_node_is_gather_broadcast() {
        // nodes == 1: the tree degenerates to reduce-onto-leader +
        // broadcast; still must sum exactly on integer data
        run_workers(3, |h| {
            let r = h.rank();
            let mut c = TopoComm::new(h, Topology::new(3, 3).unwrap())?;
            let mut buf: Vec<f32> = (0..11).map(|i| (r * 10 + i) as f32).collect();
            c.all_reduce_sum(&mut buf)?;
            let want: Vec<f32> = (0..11)
                .map(|i| (0..3).map(|q| (q * 10 + i) as f32).sum())
                .collect();
            assert_eq!(buf, want);
            Ok(())
        })
        .unwrap();
    }

    #[test]
    fn hier_gradsync_composes_for_free() {
        use crate::coordinator::{ExpertMode, GradSync};
        use crate::runtime::SyncTag;
        use crate::tensor::TensorF32;
        // GradSync's overlapped bucketed sync over a hier TopoComm must
        // be bitwise-identical to its blocking sync over the same hier
        // TopoComm (one shared tree schedule underneath both).
        run_workers(4, |h| {
            let r = h.rank();
            let mut c = TopoComm::new(h, Topology::new(4, 2).unwrap())?;
            let grads: Vec<TensorF32> = [130usize, 7, 64, 3]
                .iter()
                .enumerate()
                .map(|(t, &n)| {
                    TensorF32::from_vec(
                        &[n],
                        (0..n)
                            .map(|i| ((r * 31 + t * 7 + i) % 97) as f32 * 0.013 - 0.4)
                            .collect(),
                    )
                    .unwrap()
                })
                .collect();
            let tags = [SyncTag::World; 4];
            let blocking = GradSync::world(4, ExpertMode::Sharded);
            let mut overlapped = GradSync::world(4, ExpertMode::Sharded);
            overlapped.overlap = true;
            overlapped.bucket_bytes = 256;
            let mut a = grads.clone();
            blocking.sync(&mut c, &mut a, &tags)?;
            let mut b = grads;
            overlapped.sync(&mut c, &mut b, &tags)?;
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.data, y.data, "tensor {i}: hier overlap changed bits");
            }
            Ok(())
        })
        .unwrap();
    }
}
