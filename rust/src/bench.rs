//! Benchmark harness substrate (no `criterion` in the offline registry).
//!
//! Warmup + timed iterations with mean/p50/p95, GFLOP/s helpers, and a
//! fixed-width table printer so each `rust/benches/fig*.rs` binary emits
//! rows shaped like the paper's tables/figures.

use std::time::Instant;

use crate::metrics::Summary;

/// One benchmark configuration.
#[derive(Clone, Debug)]
pub struct BenchOpts {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        // the paper: "several warm-up rounds … executed 16 times"
        Self { warmup: 3, iters: 16 }
    }
}

impl BenchOpts {
    /// Scale iteration counts down for very slow cases.
    pub fn quick() -> Self {
        Self { warmup: 1, iters: 5 }
    }

    pub fn from_env() -> Self {
        let mut o = Self::default();
        if let Ok(v) = std::env::var("FASTMOE_BENCH_ITERS") {
            if let Ok(n) = v.parse() {
                o.iters = n;
            }
        }
        if let Ok(v) = std::env::var("FASTMOE_BENCH_WARMUP") {
            if let Ok(n) = v.parse() {
                o.warmup = n;
            }
        }
        o
    }
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub secs: Summary,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.secs.mean()
    }

    pub fn gflops(&self, flops: f64) -> f64 {
        crate::util::gflops(flops, self.secs.mean())
    }
}

/// Time `f` with warmup; `f` should perform one full operation.
pub fn bench<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup {
        f();
    }
    let mut secs = Summary::new();
    for _ in 0..opts.iters {
        let t = Instant::now();
        f();
        secs.add(t.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), secs }
}

/// Fixed-width results table, paper-figure style.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    /// Also emit the table as CSV (for EXPERIMENTS.md regeneration).
    pub fn to_csv(&self) -> String {
        let mut s = self.headers.join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }
}

/// `fmt` helpers for table cells.
pub fn ms(secs: f64) -> String {
    format!("{:.3}", secs * 1e3)
}

pub fn gf(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_expected_iterations() {
        let mut calls = 0;
        let r = bench("t", &BenchOpts { warmup: 2, iters: 5 }, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(r.secs.n, 5);
        assert!(r.mean_secs() >= 0.0);
    }

    #[test]
    fn gflops_sane() {
        let r = bench("t", &BenchOpts { warmup: 0, iters: 3 }, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        let g = r.gflops(2e6); // 2 MFLOP in ~2 ms → ~1 GFLOP/s
        assert!(g > 0.1 && g < 10.0, "g={g}");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["n", "value"]);
        t.row(vec!["1".into(), "10.0".into()]);
        t.row(vec!["100".into(), "3.5".into()]);
        let s = t.render();
        assert!(s.contains("n  value") || s.contains("  n  value"));
        assert_eq!(s.lines().count(), 4);
        let csv = t.to_csv();
        assert_eq!(csv.lines().next().unwrap(), "n,value");
    }
}
