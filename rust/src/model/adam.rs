//! Host-side Adam — bit-compatible with `python/compile/train.py`.
//!
//! Used on the distributed path (grad_step artifact + GradSync + this);
//! the fused path runs the same update inside the train-step HLO.

use crate::error::{Error, Result};
use crate::tensor::TensorF32;

pub const B1: f32 = 0.9;
pub const B2: f32 = 0.999;
pub const EPS: f32 = 1e-8;

/// Adam state for one parameter set.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub weight_decay: f32,
    pub m: Vec<TensorF32>,
    pub v: Vec<TensorF32>,
    pub step: u64,
}

impl Adam {
    pub fn new(shapes: &[TensorF32], lr: f32) -> Adam {
        Adam {
            lr,
            weight_decay: 0.0,
            m: shapes.iter().map(|t| TensorF32::zeros(&t.shape)).collect(),
            v: shapes.iter().map(|t| TensorF32::zeros(&t.shape)).collect(),
            step: 0,
        }
    }

    /// Apply one update over all parameters given their gradients.
    pub fn update(&mut self, params: &mut [TensorF32], grads: &[TensorF32]) -> Result<()> {
        let mut ps: Vec<&mut TensorF32> = params.iter_mut().collect();
        let gs: Vec<&TensorF32> = grads.iter().collect();
        self.update_refs(&mut ps, &gs)
    }

    /// Same update over *borrowed* parameters — lets callers whose
    /// tensors live in different owners (gate params on the layer,
    /// expert params behind the `ExpertShard` trait's named slots)
    /// drive one optimiser without copying into a contiguous vec.
    pub fn update_refs(
        &mut self,
        params: &mut [&mut TensorF32],
        grads: &[&TensorF32],
    ) -> Result<()> {
        if params.len() != self.m.len() || grads.len() != self.m.len() {
            return Err(Error::Shape("adam arity".into()));
        }
        self.begin_step();
        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            self.update_slot(i, p, g)?;
        }
        Ok(())
    }

    /// Advance the shared step counter: every [`Adam::update_slot`]
    /// call until the next `begin_step` applies this step's bias
    /// correction.  `update` / `update_refs` call it internally — use
    /// it directly only when stepping disjoint parameter subsets as
    /// their gradient buckets complete (the overlapped trainer path),
    /// making sure each slot is updated exactly once per step.
    pub fn begin_step(&mut self) {
        self.step += 1;
    }

    /// Update one parameter slot under the current step — bit-identical
    /// to the same slot's update inside [`Adam::update_refs`].
    pub fn update_slot(
        &mut self,
        slot: usize,
        p: &mut TensorF32,
        g: &TensorF32,
    ) -> Result<()> {
        if slot >= self.m.len() {
            return Err(Error::Shape(format!(
                "adam: slot {slot} of {}",
                self.m.len()
            )));
        }
        if self.step == 0 {
            return Err(Error::Shape("adam: update_slot before begin_step".into()));
        }
        if p.shape != g.shape {
            return Err(Error::Shape(format!(
                "adam: param {:?} vs grad {:?}",
                p.shape, g.shape
            )));
        }
        let t = self.step as f32;
        let bc1 = 1.0 - B1.powf(t);
        let bc2 = 1.0 - B2.powf(t);
        let (m, v) = (&mut self.m[slot], &mut self.v[slot]);
        for i in 0..p.data.len() {
            let gi = g.data[i];
            m.data[i] = B1 * m.data[i] + (1.0 - B1) * gi;
            v.data[i] = B2 * v.data[i] + (1.0 - B2) * gi * gi;
            let mhat = m.data[i] / bc1;
            let vhat = v.data[i] / bc2;
            p.data[i] -=
                self.lr * (mhat / (vhat.sqrt() + EPS) + self.weight_decay * p.data[i]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_closed_form() {
        // With zero state, step 1 gives p -= lr * g/(|g| + eps·√bc2/…)
        // ≈ p -= lr * sign(g) for any g (bias corrections cancel).
        let mut p = vec![TensorF32::from_vec(&[2], vec![1.0, -2.0]).unwrap()];
        let g = vec![TensorF32::from_vec(&[2], vec![0.5, -0.25]).unwrap()];
        let mut opt = Adam::new(&p, 0.1);
        opt.update(&mut p, &g).unwrap();
        assert!((p[0].data[0] - (1.0 - 0.1)).abs() < 1e-4, "{}", p[0].data[0]);
        assert!((p[0].data[1] - (-2.0 + 0.1)).abs() < 1e-4);
        assert_eq!(opt.step, 1);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimise f(x) = (x-3)², grad = 2(x-3)
        let mut p = vec![TensorF32::from_vec(&[1], vec![0.0]).unwrap()];
        let mut opt = Adam::new(&p, 0.1);
        for _ in 0..300 {
            let g = vec![TensorF32::from_vec(&[1], vec![2.0 * (p[0].data[0] - 3.0)]).unwrap()];
            opt.update(&mut p, &g).unwrap();
        }
        assert!((p[0].data[0] - 3.0).abs() < 0.05, "x={}", p[0].data[0]);
    }

    #[test]
    fn update_refs_matches_update_bitwise() {
        let mut pa = vec![
            TensorF32::from_vec(&[2], vec![1.0, -2.0]).unwrap(),
            TensorF32::from_vec(&[3], vec![0.5, 0.0, -0.5]).unwrap(),
        ];
        let mut pb = pa.clone();
        let g = vec![
            TensorF32::from_vec(&[2], vec![0.5, -0.25]).unwrap(),
            TensorF32::from_vec(&[3], vec![-0.1, 0.2, 0.3]).unwrap(),
        ];
        let mut oa = Adam::new(&pa, 0.05);
        let mut ob = oa.clone();
        for _ in 0..3 {
            oa.update(&mut pa, &g).unwrap();
            let (b0, b1) = pb.split_at_mut(1);
            let mut refs = vec![&mut b0[0], &mut b1[0]];
            ob.update_refs(&mut refs, &[&g[0], &g[1]]).unwrap();
        }
        assert_eq!(pa[0].data, pb[0].data);
        assert_eq!(pa[1].data, pb[1].data);
        assert_eq!(oa.step, ob.step);
    }

    #[test]
    fn slotwise_update_matches_update_bitwise() {
        // the overlapped trainer steps buckets out of order as they
        // complete — per-slot updates under one begin_step must be
        // bit-identical to the all-at-once update
        let mut pa = vec![
            TensorF32::from_vec(&[2], vec![1.0, -2.0]).unwrap(),
            TensorF32::from_vec(&[3], vec![0.5, 0.0, -0.5]).unwrap(),
            TensorF32::from_vec(&[1], vec![4.0]).unwrap(),
        ];
        let mut pb = pa.clone();
        let g = vec![
            TensorF32::from_vec(&[2], vec![0.5, -0.25]).unwrap(),
            TensorF32::from_vec(&[3], vec![-0.1, 0.2, 0.3]).unwrap(),
            TensorF32::from_vec(&[1], vec![-1.0]).unwrap(),
        ];
        let mut oa = Adam::new(&pa, 0.05);
        let mut ob = oa.clone();
        for _ in 0..3 {
            oa.update(&mut pa, &g).unwrap();
            ob.begin_step();
            // buckets complete out of order
            for i in [2usize, 0, 1] {
                ob.update_slot(i, &mut pb[i], &g[i]).unwrap();
            }
        }
        for (a, b) in pa.iter().zip(&pb) {
            assert_eq!(a.data, b.data);
        }
        assert_eq!(oa.step, ob.step);
        // guard rails
        let mut fresh = Adam::new(&pa, 0.05);
        assert!(fresh.update_slot(0, &mut pa[0], &g[0]).is_err(), "no begin_step");
        fresh.begin_step();
        assert!(fresh.update_slot(9, &mut pa[0], &g[0]).is_err(), "slot range");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut p = vec![TensorF32::zeros(&[2])];
        let g = vec![TensorF32::zeros(&[3])];
        let mut opt = Adam::new(&p, 0.1);
        assert!(opt.update(&mut p, &g).is_err());
    }

    #[test]
    fn matches_python_reference_values() {
        // Pinned against compile/train.py adam_update on a worked example:
        // p=1.0, g=0.3, m=v=0, step=1, lr=0.01 → m=0.03, v=9e-5,
        // mhat=0.3, vhat=0.09, p' = 1 - 0.01*0.3/(0.3+1e-8) ≈ 0.99
        let mut p = vec![TensorF32::from_vec(&[1], vec![1.0]).unwrap()];
        let g = vec![TensorF32::from_vec(&[1], vec![0.3]).unwrap()];
        let mut opt = Adam::new(&p, 0.01);
        opt.update(&mut p, &g).unwrap();
        assert!((p[0].data[0] - 0.99).abs() < 1e-6, "{}", p[0].data[0]);
        assert!((opt.m[0].data[0] - 0.03).abs() < 1e-8);
        assert!((opt.v[0].data[0] - 9e-5).abs() < 5e-9); // f32 (1-B2) rounding
    }
}
