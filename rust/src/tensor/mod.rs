//! Host tensors — the data that crosses the Rust ⇄ PJRT boundary.
//!
//! A deliberately small row-major f32/i32 tensor type.  Heavy math stays
//! in the AOT-compiled XLA programs; this module only provides what the
//! coordinator itself needs: buffer management, the elementwise math of
//! gradient sync / Adam, row packing for the all-to-all, and small
//! reference matmuls for tests.

pub mod ops;
pub mod pool;

pub use ops::*;
pub use pool::{BufferPool, PoolStats};

use crate::error::{Error, Result};

/// Row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorF32 {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

/// Row-major i32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

/// A host tensor of either runtime dtype (mirrors the manifest ABI).
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(TensorF32),
    I32(TensorI32),
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl TensorF32 {
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; numel(shape)] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { shape: shape.to_vec(), data: vec![v; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        if numel(shape) != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} needs {} elements, got {}",
                shape,
                numel(shape),
                data.len()
            )));
        }
        Ok(Self { shape: shape.to_vec(), data })
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Rows × cols view of a rank-2 tensor.
    pub fn dims2(&self) -> Result<(usize, usize)> {
        match self.shape.as_slice() {
            [r, c] => Ok((*r, *c)),
            s => Err(Error::Shape(format!("expected rank-2, got {s:?}"))),
        }
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let (_, c) = (self.shape[0], self.shape[1]);
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.shape[1];
        &mut self.data[r * c..(r + 1) * c]
    }

    /// Raw little-endian byte view (for PJRT literal construction).
    pub fn as_bytes(&self) -> &[u8] {
        // Safety: f32 has no invalid bit patterns and we only reinterpret
        // for reading; alignment of u8 is 1.
        unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const u8,
                self.data.len() * 4,
            )
        }
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

impl TensorI32 {
    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0; numel(shape)] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        if numel(shape) != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} needs {} elements, got {}",
                shape,
                numel(shape),
                data.len()
            )));
        }
        Ok(Self { shape: shape.to_vec(), data })
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn as_bytes(&self) -> &[u8] {
        unsafe {
            std::slice::from_raw_parts(
                self.data.as_ptr() as *const u8,
                self.data.len() * 4,
            )
        }
    }
}

impl HostTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(t) => &t.shape,
            HostTensor::I32(t) => &t.shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            HostTensor::F32(_) => "f32",
            HostTensor::I32(_) => "i32",
        }
    }

    pub fn as_f32(&self) -> Result<&TensorF32> {
        match self {
            HostTensor::F32(t) => Ok(t),
            _ => Err(Error::Shape("expected f32 tensor".into())),
        }
    }

    pub fn into_f32(self) -> Result<TensorF32> {
        match self {
            HostTensor::F32(t) => Ok(t),
            _ => Err(Error::Shape("expected f32 tensor".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&TensorI32> {
        match self {
            HostTensor::I32(t) => Ok(t),
            _ => Err(Error::Shape("expected i32 tensor".into())),
        }
    }
}

impl From<TensorF32> for HostTensor {
    fn from(t: TensorF32) -> Self {
        HostTensor::F32(t)
    }
}

impl From<TensorI32> for HostTensor {
    fn from(t: TensorI32) -> Self {
        HostTensor::I32(t)
    }
}

/// A *borrowed* host tensor — the zero-clone argument type of
/// `runtime::Executable::run_refs`.
///
/// `Executable::run` historically took owned [`HostTensor`]s, which
/// forced every caller on the hot path to clone its (often large,
/// step-invariant) inputs just to build the argument list; the PJRT
/// literal construction copies the bytes again anyway.  A
/// `HostTensorRef` borrows instead, so expert weights and padded
/// batches go host→literal exactly once per call.
#[derive(Clone, Copy, Debug)]
pub enum HostTensorRef<'a> {
    F32(&'a TensorF32),
    I32(&'a TensorI32),
}

impl HostTensorRef<'_> {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensorRef::F32(t) => &t.shape,
            HostTensorRef::I32(t) => &t.shape,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            HostTensorRef::F32(_) => "f32",
            HostTensorRef::I32(_) => "i32",
        }
    }

    pub fn as_bytes(&self) -> &[u8] {
        match self {
            HostTensorRef::F32(t) => t.as_bytes(),
            HostTensorRef::I32(t) => t.as_bytes(),
        }
    }
}

impl<'a> From<&'a TensorF32> for HostTensorRef<'a> {
    fn from(t: &'a TensorF32) -> Self {
        HostTensorRef::F32(t)
    }
}

impl<'a> From<&'a TensorI32> for HostTensorRef<'a> {
    fn from(t: &'a TensorI32) -> Self {
        HostTensorRef::I32(t)
    }
}

impl<'a> From<&'a HostTensor> for HostTensorRef<'a> {
    fn from(t: &'a HostTensor) -> Self {
        match t {
            HostTensor::F32(t) => HostTensorRef::F32(t),
            HostTensor::I32(t) => HostTensorRef::I32(t),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_checks_shape() {
        assert!(TensorF32::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(TensorF32::from_vec(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn rows() {
        let t = TensorF32::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn bytes_roundtrip() {
        let t = TensorF32::from_vec(&[3], vec![1.0, -2.5, 3.25]).unwrap();
        let b = t.as_bytes();
        assert_eq!(b.len(), 12);
        let back = f32::from_le_bytes([b[4], b[5], b[6], b[7]]);
        assert_eq!(back, -2.5);
    }

    #[test]
    fn host_tensor_dtype_guards() {
        let f: HostTensor = TensorF32::zeros(&[2]).into();
        assert!(f.as_f32().is_ok());
        assert!(f.as_i32().is_err());
        assert_eq!(f.dtype(), "f32");
    }

    #[test]
    fn tensor_ref_borrows_without_copying() {
        let t = TensorF32::from_vec(&[2], vec![1.0, 2.0]).unwrap();
        let r: HostTensorRef = (&t).into();
        assert_eq!(r.shape(), &[2]);
        assert_eq!(r.dtype(), "f32");
        assert_eq!(r.as_bytes().as_ptr(), t.as_bytes().as_ptr());
        let h: HostTensor = t.clone().into();
        let hr: HostTensorRef = (&h).into();
        assert_eq!(hr.shape(), &[2]);
    }

    #[test]
    fn scalar_shape() {
        let s = TensorF32::scalar(4.0);
        assert_eq!(s.shape, Vec::<usize>::new());
        assert_eq!(s.numel(), 1);
    }
}
