"""AOT lowering driver: every run-time HLO program is produced here, once.

``python -m compile.aot --out-dir ../artifacts --preset default``

For each artifact we lower a jitted Layer-2 closure to **HLO text** (not a
serialized ``HloModuleProto`` — jax >= 0.5 emits 64-bit instruction ids
that the xla_extension 0.5.1 parser rejects; the text parser reassigns
ids and round-trips cleanly) and record its ABI — input/output names,
shapes, dtypes — plus the parameter registry of each model in
``manifest.json``.  The Rust runtime (rust/src/runtime) consumes only the
manifest and the ``.hlo.txt`` files.
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import hashlib
import json
import os
import sys
import time
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import gpt, stages, train
from .kernels import expert_ffn as expert_ffn_mod


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Preset:
    """One compile-time configuration of every artifact family."""

    name: str
    # fig-5 fused layer family
    nb: int
    d_model: int
    d_hidden: int
    top_k: int
    expert_counts: Tuple[int, ...]
    # distributed stage family
    ne_local: int
    worker_counts: Tuple[int, ...]
    buckets: Tuple[int, ...]
    # fig-7 model family
    gpt: gpt.GptConfig
    gpt_batch: int
    lr: float = 3e-4


def _gpt_cfg(moe: bool, **kw) -> gpt.GptConfig:
    return gpt.GptConfig(moe=moe, **kw)


PRESETS: Dict[str, Preset] = {
    "tiny": Preset(
        name="tiny",
        nb=64, d_model=32, d_hidden=64, top_k=2, expert_counts=(1, 2, 4),
        ne_local=2, worker_counts=(1, 2, 4), buckets=(16, 32, 64, 128),
        gpt=_gpt_cfg(True, vocab=64, seq=16, n_layer=2, d_model=32, n_head=2,
                     d_hidden=64, n_expert=4, top_k=2),
        gpt_batch=2,
    ),
    "default": Preset(
        name="default",
        nb=512, d_model=256, d_hidden=1024, top_k=2,
        expert_counts=(1, 2, 4, 8, 16),
        ne_local=4, worker_counts=(1, 2, 4, 8),
        buckets=(64, 128, 256, 512, 1024, 2048),
        gpt=_gpt_cfg(True, vocab=256, seq=128, n_layer=4, d_model=256,
                     n_head=8, d_hidden=1024, n_expert=16, top_k=2),
        gpt_batch=4,
    ),
    # Paper-scale shapes (V100 experiment of §5): compile-only sanity —
    # lowering these proves the kernels/BlockSpecs handle the real sizes.
    "paper": Preset(
        name="paper",
        nb=4096, d_model=1024, d_hidden=4096, top_k=2,
        expert_counts=(2, 4, 8, 16),
        ne_local=4, worker_counts=(2, 4, 8), buckets=(1024, 2048, 4096, 8192),
        gpt=_gpt_cfg(True, vocab=50257, seq=1024, n_layer=12, d_model=1024,
                     n_head=16, d_hidden=4096, n_expert=96, top_k=2),
        gpt_batch=1,
    ),
}


# ---------------------------------------------------------------------------
# Lowering machinery
# ---------------------------------------------------------------------------

DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


@dataclasses.dataclass
class Artifact:
    name: str
    fn: Callable
    inputs: List[Tuple[str, jax.ShapeDtypeStruct]]
    meta: Dict

    def lower(self) -> Tuple[str, List[Dict], List[Dict]]:
        in_specs = [s for _, s in self.inputs]
        # keep_unused: the positional ABI is part of the manifest
        # contract — jit must not prune arguments the backward pass
        # doesn't read (e.g. b2 in expert_bwd).
        lowered = jax.jit(self.fn, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        out_tree = jax.eval_shape(self.fn, *in_specs)
        outs = jax.tree_util.tree_leaves(out_tree)
        in_desc = [
            {"name": n, "shape": list(s.shape), "dtype": DTYPE_NAMES[s.dtype]}
            for n, s in self.inputs
        ]
        out_desc = [
            {"index": i, "shape": list(o.shape), "dtype": DTYPE_NAMES[o.dtype]}
            for i, o in enumerate(outs)
        ]
        return text, in_desc, out_desc


def f32(*shape):
    return spec(shape, jnp.float32)


def i32(*shape):
    return spec(shape, jnp.int32)


# ---------------------------------------------------------------------------
# Artifact registry per preset
# ---------------------------------------------------------------------------

def build_artifacts(p: Preset) -> List[Artifact]:
    arts: List[Artifact] = []
    d, dh, k, nb = p.d_model, p.d_hidden, p.top_k, p.nb

    # ---- Figure 5: fused FastMoE layer vs naive baseline, fwd and grad ----
    for ne in p.expert_counts:
        cap = gpt.layers.capacity_for(nb, k, ne)
        de = dh  # fig-5 compares at fixed expert size, like the paper
        layer_inputs = [
            ("x", f32(nb, d)), ("wg", f32(d, ne)), ("bg", f32(ne)),
            ("w1", f32(ne, d, de)), ("b1", f32(ne, de)),
            ("w2", f32(ne, de, d)), ("b2", f32(ne, d)),
        ]
        meta = {"family": "fig5", "nb": nb, "d_model": d, "d_hidden": de,
                "n_expert": ne, "top_k": k, "capacity": cap}
        arts.append(Artifact(
            f"moe_fwd_e{ne}",
            functools.partial(stages.fused_moe_fwd, k=k, capacity=cap),
            layer_inputs, {**meta, "kind": "fused_fwd"}))
        arts.append(Artifact(
            f"moe_grad_e{ne}",
            functools.partial(stages.fused_moe_grad, k=k, capacity=cap),
            layer_inputs, {**meta, "kind": "fused_grad"}))
        arts.append(Artifact(
            f"naive_fwd_e{ne}",
            functools.partial(stages.naive_moe_fwd, k=k),
            layer_inputs, {**meta, "kind": "naive_fwd"}))
        arts.append(Artifact(
            f"naive_grad_e{ne}",
            functools.partial(stages.naive_moe_grad, k=k),
            layer_inputs, {**meta, "kind": "naive_grad"}))

    # ---- Figure 3 support: single dense FFN (per-sample GEMV loop driver
    # slices rows out of it; the sweep itself is built with XlaBuilder) ----
    arts.append(Artifact(
        "dense_ffn",
        stages.dense_ffn_fwd,
        [("x", f32(nb, d)), ("w1", f32(d, dh)), ("b1", f32(dh)),
         ("w2", f32(dh, d)), ("b2", f32(d))],
        {"family": "fig3", "nb": nb, "d_model": d, "d_hidden": dh,
         "kind": "dense_fwd"}))

    # ---- Distributed stage graphs (Figure 6 / distributed examples) ----
    for w in p.worker_counts:
        eg = w * p.ne_local
        arts.append(Artifact(
            f"gate_fwd_w{w}", stages.gate_fwd,
            [("x", f32(nb, d)), ("wg", f32(d, eg)), ("bg", f32(eg))],
            {"family": "stage", "kind": "gate_fwd", "nb": nb, "d_model": d,
             "n_expert_global": eg, "workers": w}))
        arts.append(Artifact(
            f"gate_bwd_w{w}", stages.gate_bwd,
            [("x", f32(nb, d)), ("wg", f32(d, eg)), ("dscores", f32(nb, eg))],
            {"family": "stage", "kind": "gate_bwd", "nb": nb, "d_model": d,
             "n_expert_global": eg, "workers": w}))
    for b in p.buckets:
        de = dh
        shard = [
            ("xs", f32(p.ne_local, b, d)),
            ("w1", f32(p.ne_local, d, de)), ("b1", f32(p.ne_local, de)),
            ("w2", f32(p.ne_local, de, d)), ("b2", f32(p.ne_local, d)),
        ]
        arts.append(Artifact(
            f"expert_fwd_b{b}", stages.expert_fwd, shard,
            {"family": "stage", "kind": "expert_fwd", "bucket": b,
             "ne_local": p.ne_local, "d_model": d, "d_hidden": de}))
        arts.append(Artifact(
            f"expert_bwd_b{b}", stages.expert_bwd,
            shard + [("dys", f32(p.ne_local, b, d))],
            {"family": "stage", "kind": "expert_bwd", "bucket": b,
             "ne_local": p.ne_local, "d_model": d, "d_hidden": de}))
    n_slots = nb * k
    arts.append(Artifact(
        "combine_fwd", stages.combine_fwd,
        [("ys", f32(n_slots, d)), ("slots", i32(nb, k)), ("w", f32(nb, k))],
        {"family": "stage", "kind": "combine_fwd", "nb": nb, "top_k": k,
         "n_slots": n_slots, "d_model": d}))
    arts.append(Artifact(
        "combine_bwd", stages.combine_bwd,
        [("ys", f32(n_slots, d)), ("slots", i32(nb, k)), ("w", f32(nb, k)),
         ("dout", f32(nb, d))],
        {"family": "stage", "kind": "combine_bwd", "nb": nb, "top_k": k,
         "n_slots": n_slots, "d_model": d}))

    # ---- Figure 7: fused GPT train/eval/grad steps, MoE and dense ----
    for moe in (True, False):
        cfg = dataclasses.replace(p.gpt, moe=moe)
        tag = "moe" if moe else "dense"
        specs = gpt.param_specs(cfg)
        tok = i32(p.gpt_batch, cfg.seq)
        pspecs = [(s.name, f32(*s.shape)) for s in specs]

        step_fn, _ = train.make_train_step(cfg, lr=p.lr)
        arts.append(Artifact(
            f"train_step_{tag}", step_fn,
            [("tokens", tok), ("targets", tok), ("step", f32())]
            + pspecs
            + [(f"m:{n}", s) for n, s in pspecs]
            + [(f"v:{n}", s) for n, s in pspecs],
            {"family": "fig7", "kind": "train_step", "model": f"gpt_{tag}",
             "batch": p.gpt_batch, "lr": p.lr}))

        eval_fn, _ = train.make_eval_step(cfg)
        arts.append(Artifact(
            f"eval_step_{tag}", eval_fn,
            [("tokens", tok), ("targets", tok)] + pspecs,
            {"family": "fig7", "kind": "eval_step", "model": f"gpt_{tag}",
             "batch": p.gpt_batch}))

        grad_fn, _ = train.make_grad_step(cfg)
        arts.append(Artifact(
            f"grad_step_{tag}", grad_fn,
            [("tokens", tok), ("targets", tok)] + pspecs,
            {"family": "fig7", "kind": "grad_step", "model": f"gpt_{tag}",
             "batch": p.gpt_batch}))

    # ---- §6 future-work feature: balance-loss train step ----
    cfg_bal = dataclasses.replace(p.gpt, moe=True)
    specs = gpt.param_specs(cfg_bal)
    tok = i32(p.gpt_batch, cfg_bal.seq)
    pspecs = [(s.name, f32(*s.shape)) for s in specs]
    bal_fn, _ = train.make_train_step(cfg_bal, lr=p.lr, balance_coef=0.01)
    arts.append(Artifact(
        "train_step_moe_bal", bal_fn,
        [("tokens", tok), ("targets", tok), ("step", f32())]
        + pspecs
        + [(f"m:{n}", s) for n, s in pspecs]
        + [(f"v:{n}", s) for n, s in pspecs],
        {"family": "fig7", "kind": "train_step", "model": "gpt_moe_bal",
         "batch": p.gpt_batch, "lr": p.lr, "balance_coef": 0.01}))

    # ---- quickstart: one small fused MoE layer ----
    qne, qnb, qd, qdh = 4, 64, 32, 64
    qcap = gpt.layers.capacity_for(qnb, 2, qne)
    arts.append(Artifact(
        "quickstart_moe",
        functools.partial(stages.fused_moe_fwd, k=2, capacity=qcap),
        [("x", f32(qnb, qd)), ("wg", f32(qd, qne)), ("bg", f32(qne)),
         ("w1", f32(qne, qd, qdh)), ("b1", f32(qne, qdh)),
         ("w2", f32(qne, qdh, qd)), ("b2", f32(qne, qd))],
        {"family": "quickstart", "kind": "fused_fwd", "nb": qnb,
         "d_model": qd, "d_hidden": qdh, "n_expert": qne, "top_k": 2,
         "capacity": qcap}))

    return arts


def model_manifest(p: Preset) -> Dict:
    models = {}
    for moe in (True, False):
        cfg = dataclasses.replace(p.gpt, moe=moe)
        tag = "moe" if moe else "dense"
        models[f"gpt_{tag}"] = {
            "config": {
                "vocab": cfg.vocab, "seq": cfg.seq, "n_layer": cfg.n_layer,
                "d_model": cfg.d_model, "n_head": cfg.n_head,
                "d_hidden": cfg.d_hidden, "moe": cfg.moe,
                "n_expert": cfg.n_expert, "top_k": cfg.top_k,
                "batch": p.gpt_batch,
                "flops_per_token": gpt.model_flops_per_token(cfg),
            },
            "params": [
                {"name": s.name, "shape": list(s.shape), "init": s.init,
                 "tag": s.tag}
                for s in gpt.param_specs(cfg)
            ],
            "train_step": f"train_step_{tag}",
            "eval_step": f"eval_step_{tag}",
            "grad_step": f"grad_step_{tag}",
        }
    # gpt_moe with the balance-loss train step; identical registry
    models["gpt_moe_bal"] = dict(
        models["gpt_moe"], train_step="train_step_moe_bal"
    )
    return models


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="default", choices=sorted(PRESETS))
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    ap.add_argument("--report", action="store_true",
                    help="print VMEM/roofline estimates and exit")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the manifest is up to date")
    args = ap.parse_args(argv)

    p = PRESETS[args.preset]
    if args.report:
        vf = expert_ffn_mod.vmem_floats(p.d_model, p.d_hidden)
        print(f"preset={p.name}")
        print(f"expert_ffn VMEM/step: {vf} floats = {vf*4/2**20:.2f} MiB "
              f"(budget ~16 MiB)")
        for moe in (True, False):
            cfg = dataclasses.replace(p.gpt, moe=moe)
            n_params = sum(
                int(jnp.prod(jnp.array(s.shape))) for s in gpt.param_specs(cfg)
            )
            print(f"gpt_{'moe' if moe else 'dense'}: params={n_params:,} "
                  f"flops/token={gpt.model_flops_per_token(cfg):,}")
        return 0

    os.makedirs(args.out_dir, exist_ok=True)
    arts = build_artifacts(p)
    if args.only:
        arts = [a for a in arts if args.only in a.name]

    manifest = {
        "version": 1,
        "preset": p.name,
        "preset_params": {
            "nb": p.nb, "d_model": p.d_model, "d_hidden": p.d_hidden,
            "top_k": p.top_k, "expert_counts": list(p.expert_counts),
            "ne_local": p.ne_local, "worker_counts": list(p.worker_counts),
            "buckets": list(p.buckets),
        },
        "artifacts": [],
        "models": model_manifest(p),
    }

    t_all = time.time()
    for a in arts:
        path = os.path.join(args.out_dir, f"{a.name}.hlo.txt")
        t0 = time.time()
        text, in_desc, out_desc = a.lower()
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"].append({
            "name": a.name,
            "file": f"{a.name}.hlo.txt",
            "sha256_16": digest,
            "inputs": in_desc,
            "outputs": out_desc,
            "meta": a.meta,
        })
        print(f"  lowered {a.name:24s} {len(text)//1024:6d} KiB "
              f"in {time.time()-t0:6.1f}s", flush=True)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(arts)} artifacts + manifest.json "
          f"({time.time()-t_all:.1f}s total)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
