//! Quickstart: run an AOT-compiled MoE layer, then assemble the
//! expert-parallel layer through the hierarchical `MoeLayerBuilder`.
//!
//! ```bash
//! make artifacts            # once: python lowers the HLO programs
//! cargo run --release --example quickstart
//! ```
//!
//! Part 1 is the three-layer story in a few lines: the Pallas kernels
//! and the JAX layer were lowered at build time; at run time Rust loads
//! the HLO text, compiles it on the PJRT CPU client, and executes it —
//! no python anywhere.
//!
//! Part 2 is the paper's §3.1 hierarchy: the same dispatch substrate
//! with a *config-selected* gate policy — here `noisy_topk` from an
//! inline `[moe]` section — driven through `MoeLayerBuilder`.

use std::sync::Arc;

use fastmoe::comm::run_workers;
use fastmoe::config::ConfigFile;
use fastmoe::coordinator::MoeLayerBuilder;
use fastmoe::metrics::Counters;
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::tensor::{HostTensor, TensorF32};

fn main() -> fastmoe::Result<()> {
    // 1. Open the artifact directory (reads manifest.json).
    let rt = Arc::new(Runtime::open_default()?);
    println!("PJRT platform: {}", rt.platform());

    // 2. Compile the fused MoE layer (gate → scatter → experts → combine).
    let exe = rt.executable("quickstart_moe")?;
    let meta = &exe.meta;
    println!(
        "artifact `{}`: {} experts, top-{}, batch {} × d_model {}",
        meta.name,
        meta.meta_usize("n_expert").unwrap(),
        meta.meta_usize("top_k").unwrap(),
        meta.meta_usize("nb").unwrap(),
        meta.meta_usize("d_model").unwrap(),
    );

    // 3. Build random inputs straight from the manifest ABI.
    let mut rng = Rng::new(42);
    let inputs: Vec<HostTensor> = meta
        .inputs
        .iter()
        .map(|spec| {
            let mut t = TensorF32::zeros(&spec.shape);
            rng.fill_normal(&mut t.data, 0.5);
            HostTensor::F32(t)
        })
        .collect();

    // 4. Execute and inspect.
    let outputs = exe.run(&inputs)?;
    let y = outputs[0].as_f32()?;
    println!(
        "output: shape {:?}, ‖y‖₂ = {:.4}, first row: {:?}",
        y.shape,
        y.l2_norm(),
        &y.row(0)[..4.min(y.shape[1])]
    );

    // 5. The hierarchical API: pick a non-default gate from config and
    //    let the builder assemble gate + expert shard + dispatch.
    let cfg = ConfigFile::parse(
        "[moe]\ngate = \"noisy_topk\"\nnoise_std = 0.5\n",
    )?
    .moe()?;
    let workers = 2;
    if rt.manifest.artifact(&format!("gate_fwd_w{workers}")).is_none() {
        println!("(no {workers}-worker stage artifacts; skipping builder demo)");
        println!("quickstart OK");
        return Ok(());
    }
    let builder = MoeLayerBuilder::from_config(&cfg).seed(7);
    let norms = run_workers(workers, {
        let rt = rt.clone();
        move |mut h| {
            let layer = builder.build_for(rt.clone(), &h)?;
            let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
            Rng::new(99).fill_normal(&mut x.data, 1.0);
            let mut counters = Counters::new();
            let (y, state) = layer.forward(&mut h, x, &mut counters)?;
            Ok((y.l2_norm(), state.balance))
        }
    })?;
    for (rank, (norm, balance)) in norms.iter().enumerate() {
        println!(
            "builder demo (gate `{}`): worker {rank} ‖y‖₂ = {norm:.4}, \
             balance_loss = {balance:.3}",
            cfg.gate
        );
    }
    println!("quickstart OK");
    Ok(())
}
