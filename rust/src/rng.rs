//! Deterministic PRNG substrate (no `rand` crate in the offline registry).
//!
//! `SplitMix64` for seeding / cheap streams and `Xoshiro256**` for bulk
//! generation, plus Box–Muller normal sampling.  Every stochastic choice
//! in the system (init, synthetic corpus, property tests) flows through
//! this module so runs are exactly reproducible from a seed.

/// SplitMix64 — tiny, solid generator used to seed others.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive an independent stream (e.g. one per worker / per parameter).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[1].wrapping_mul(5)).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)`; n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as usize) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u = self.next_f64();
            let v = self.next_f64();
            if u > f64::MIN_POSITIVE {
                let r = (-2.0 * u.ln()).sqrt();
                let theta = 2.0 * std::f64::consts::PI * v;
                self.gauss_spare = Some(r * theta.sin());
                return r * theta.cos();
            }
        }
    }

    /// Fill a slice with N(0, std²) f32 samples.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for x in out.iter_mut() {
            *x = self.normal() as f32 * std;
        }
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut v: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(5);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
