//! The two training ABIs must agree: the fused in-graph train step
//! (tokens→new params, Adam inside XLA) and the distributed path
//! (grad_step artifact + GradSync + host Adam) are the same math.
//!
//! The PR-4 suite additionally pins the *overlapped* gradient sync
//! (`[comm] grad_overlap`: bucketed nonblocking all-reduce) to be
//! **bit-identical** to blocking — at the `GradSync` level over both
//! comm backends and bucket sizes (runs without artifacts), and at the
//! trainer level for `DistTrainer` (bucket completions pipelined
//! against host Adam) and `MoeLayerTrainer` (the gate-grad bucket
//! flying during the expert backward) when artifacts are present.

use std::sync::Arc;

use fastmoe::comm::tcp::TcpGroup;
use fastmoe::comm::{run_workers, Comm, TopoComm, Topology};
use fastmoe::config::CommConfig;
use fastmoe::coordinator::{
    DistTrainer, ExpertMode, GradSync, MoeLayerBuilder, MoeLayerTrainer, Trainer,
};
use fastmoe::data::{BatchIter, Corpus};
use fastmoe::metrics::Counters;
use fastmoe::model::Adam;
use fastmoe::rng::Rng;
use fastmoe::runtime::{Runtime, SyncTag};
use fastmoe::tensor::{ops, TensorF32};

fn runtime() -> Option<Arc<Runtime>> {
    Runtime::open_default().ok().map(Arc::new)
}

/// Synthetic per-rank gradient set whose sums are order-sensitive.
fn synth_grads(rank: usize) -> Vec<TensorF32> {
    [130usize, 7, 64, 3, 200, 1]
        .iter()
        .enumerate()
        .map(|(t, &n)| {
            TensorF32::from_vec(
                &[n],
                (0..n)
                    .map(|i| {
                        ((rank * 31 + t * 7 + i) % 97) as f32 * 0.013 - 0.4
                    })
                    .collect(),
            )
            .unwrap()
        })
        .collect()
}

const SYNTH_TAGS: [SyncTag; 6] = [
    SyncTag::World,
    SyncTag::None,
    SyncTag::World,
    SyncTag::DataParallel,
    SyncTag::World,
    SyncTag::World,
];

/// Blocking vs overlapped `GradSync` on one comm handle, asserting
/// bitwise equality per tensor, across modes and bucket sizes.
fn sync_equivalence_case(h: &mut impl Comm) -> fastmoe::Result<()> {
    let grads = synth_grads(h.rank());
    for mode in [ExpertMode::Sharded, ExpertMode::Replicated] {
        for bucket_bytes in [4usize, 256, 1 << 20] {
            let blocking = GradSync::world(h.size(), mode);
            let mut overlapped = GradSync::world(h.size(), mode);
            overlapped.overlap = true;
            overlapped.bucket_bytes = bucket_bytes;
            let mut a = grads.clone();
            blocking.sync(h, &mut a, &SYNTH_TAGS)?;
            let mut b = grads.clone();
            overlapped.sync(h, &mut b, &SYNTH_TAGS)?;
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(
                    x.data, y.data,
                    "mode {mode:?} bucket_bytes {bucket_bytes} tensor {i}: \
                     overlapped grad sync changed bits"
                );
            }
        }
    }
    Ok(())
}

#[test]
fn overlapped_grad_sync_bitwise_thread_backend() {
    run_workers(4, |mut h| sync_equivalence_case(&mut h)).unwrap();
}

/// Rank-identical synthetic parameter set matching [`synth_grads`]'s
/// shapes (the zero step needs params + optimizer, not just grads).
fn synth_params() -> Vec<TensorF32> {
    [130usize, 7, 64, 3, 200, 1]
        .iter()
        .enumerate()
        .map(|(t, &n)| {
            TensorF32::from_vec(
                &[n],
                (0..n)
                    .map(|i| ((t * 13 + i) % 89) as f32 * 0.017 - 0.7)
                    .collect(),
            )
            .unwrap()
        })
        .collect()
}

/// Replicated reference (blocking sync + full-state Adam) vs the zero
/// path (reduce-scatter → shard-local Adam → all-gather of updated
/// params) over 3 steps, asserting bitwise parameter equality across
/// bucket sizes.  `topo` must match the comm's zero schedule: flat for
/// plain handles, the `TopoComm` topology for rail-sharded hier.
fn zero_equivalence_case(
    h: &mut impl Comm,
    topo: &Topology,
) -> fastmoe::Result<()> {
    let grads0 = synth_grads(h.rank());
    let params0 = synth_params();
    for bucket_bytes in [4usize, 256, 1 << 20] {
        let reference = GradSync::world(h.size(), ExpertMode::Sharded);
        let mut zero = GradSync::world(h.size(), ExpertMode::Sharded);
        zero.shard = true;
        zero.bucket_bytes = bucket_bytes;
        let mut pa = params0.clone();
        let mut oa = Adam::new(&pa, 0.01);
        let shard = zero.shard_plan(&params0, &SYNTH_TAGS, topo, h.rank());
        let mut pb = params0.clone();
        let mut ob = Adam::new_sharded(&pb, 0.01, &shard)?;
        for _ in 0..3 {
            let mut ga = grads0.clone();
            reference.sync(h, &mut ga, &SYNTH_TAGS)?;
            oa.update(&mut pa, &ga)?;
            let mut gb = grads0.clone();
            zero.sync_zero(h, &mut gb, &SYNTH_TAGS, &mut pb, &mut ob)?;
        }
        for (i, (x, y)) in pa.iter().zip(&pb).enumerate() {
            assert_eq!(
                x.data, y.data,
                "bucket_bytes {bucket_bytes} tensor {i}: zero-sharded \
                 optimizer changed parameter bits"
            );
        }
    }
    Ok(())
}

#[test]
fn zero_sharded_adam_bitwise_thread_backend() {
    run_workers(4, |mut h| {
        let topo = Topology::flat(h.size());
        zero_equivalence_case(&mut h, &topo)
    })
    .unwrap();
}

#[test]
fn zero_sharded_adam_bitwise_hier_rails() {
    // Rail-sharded zero under a 2-node hier TopoComm: each local rank
    // owns a sub-slice and rings across nodes with its peer rank.
    run_workers(4, |h| {
        let topo = Topology::new(4, 2)?;
        let mut h = TopoComm::new(h, topo)?;
        zero_equivalence_case(&mut h, &topo)
    })
    .unwrap();
}

#[test]
fn zero_sharded_adam_bitwise_tcp_backend() {
    // once over plain sockets, once with the progress engine draining
    for (port, progress) in [(47852u16, false), (47862u16, true)] {
        let joins: Vec<_> = (0..3)
            .map(|rank| {
                std::thread::spawn(move || {
                    let mut g = TcpGroup::connect_local(rank, 3, port).unwrap();
                    if progress {
                        g.enable_progress();
                    }
                    let topo = Topology::flat(3);
                    zero_equivalence_case(&mut g, &topo).unwrap();
                    g.barrier().unwrap();
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
    }
}

#[test]
fn overlapped_grad_sync_bitwise_tcp_backend() {
    // once over plain sockets, once with the progress engine draining
    for (port, progress) in [(47850u16, false), (47860u16, true)] {
        let joins: Vec<_> = (0..3)
            .map(|rank| {
                std::thread::spawn(move || {
                    let mut g = TcpGroup::connect_local(rank, 3, port).unwrap();
                    if progress {
                        g.enable_progress();
                    }
                    sync_equivalence_case(&mut g).unwrap();
                    g.barrier().unwrap();
                })
            })
            .collect();
        for j in joins {
            j.join().unwrap();
        }
    }
}

#[test]
fn overlapped_grad_sync_bit_identical_dist_trainer() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let workers = 2;
    let run = |grad_overlap: bool| {
        let rt = rt.clone();
        run_workers(workers, move |mut h| {
            let comm_cfg = CommConfig {
                grad_overlap,
                bucket_kb: 1, // force many buckets
                ..CommConfig::default()
            };
            let mut tr = DistTrainer::with_comm(
                &rt, "gpt_moe", 5, workers, h.rank(), 1e-3, &comm_cfg,
            )?;
            let vocab = tr.entry.config_usize("vocab").unwrap();
            let seq = tr.entry.config_usize("seq").unwrap();
            let batch = tr.entry.config_usize("batch").unwrap();
            let corpus = Corpus::synthetic(vocab, 100_000, 8);
            let mut it = BatchIter::shard(&corpus, batch, seq, 14, h.rank());
            let mut losses = Vec::new();
            for _ in 0..3 {
                losses.push(tr.train_step(&mut h, &it.next_batch())?);
            }
            Ok((losses, tr.params))
        })
        .unwrap()
    };
    let blocking = run(false);
    let overlapped = run(true);
    for rank in 0..workers {
        let (bl, bp) = &blocking[rank];
        let (ol, op) = &overlapped[rank];
        assert_eq!(bl, ol, "rank {rank}: losses diverged");
        for (i, (a, b)) in bp.tensors.iter().zip(&op.tensors).enumerate() {
            assert_eq!(
                a.data, b.data,
                "rank {rank} param {i} (`{}`): overlapped grad sync \
                 changed parameter bits",
                bp.entries[i].name
            );
        }
    }
}

#[test]
fn zero_sharded_dist_trainer_matches_replicated() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let workers = 2;
    let run = |grad_shard: &'static str| {
        let rt = rt.clone();
        run_workers(workers, move |mut h| {
            let comm_cfg = CommConfig {
                grad_shard: grad_shard.into(),
                bucket_kb: 1, // force many buckets
                ..CommConfig::default()
            };
            let mut tr = DistTrainer::with_comm(
                &rt, "gpt_moe", 5, workers, h.rank(), 1e-3, &comm_cfg,
            )?;
            let vocab = tr.entry.config_usize("vocab").unwrap();
            let seq = tr.entry.config_usize("seq").unwrap();
            let batch = tr.entry.config_usize("batch").unwrap();
            let corpus = Corpus::synthetic(vocab, 100_000, 8);
            let mut it = BatchIter::shard(&corpus, batch, seq, 14, h.rank());
            let mut losses = Vec::new();
            for _ in 0..3 {
                losses.push(tr.train_step(&mut h, &it.next_batch())?);
            }
            Ok((losses, tr.params))
        })
        .unwrap()
    };
    let replicated = run("none");
    let zero = run("zero");
    for rank in 0..workers {
        let (rl, rp) = &replicated[rank];
        let (zl, zp) = &zero[rank];
        assert_eq!(rl, zl, "rank {rank}: losses diverged");
        for (i, (a, b)) in rp.tensors.iter().zip(&zp.tensors).enumerate() {
            assert_eq!(
                a.data, b.data,
                "rank {rank} param {i} (`{}`): ZeRO-sharded optimizer \
                 changed parameter bits",
                rp.entries[i].name
            );
        }
    }
}

/// `MoeLayerTrainer` step loop for one config; returns final params.
fn moe_trainer_params(
    rt: Arc<Runtime>,
    workers: usize,
    grad_overlap: bool,
    overlap: bool,
    grad_shard: bool,
) -> Vec<Vec<Vec<f32>>> {
    run_workers(workers, move |mut h| {
        let layer = MoeLayerBuilder::new()
            .seed(3)
            .overlap(overlap)
            .chunks(2)
            .grad_overlap(grad_overlap)
            .grad_shard(grad_shard)
            .build(rt.clone(), workers, h.rank())?;
        let mut tr = MoeLayerTrainer::new(layer, 1e-2);
        let mut counters = Counters::new();
        for step in 0..4 {
            let mut x = TensorF32::zeros(&[tr.layer.nb, tr.layer.dm]);
            Rng::new(50 + step * 7 + h.rank() as u64).fill_normal(&mut x.data, 1.0);
            tr.train_step(&mut h, x, &mut counters)?;
        }
        Ok(tr
            .layer
            .params()
            .into_iter()
            .map(|(_, t)| t.data.clone())
            .collect::<Vec<_>>())
    })
    .unwrap()
}

#[test]
fn overlapped_gate_sync_bit_identical_moe_layer_trainer() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let workers = 2;
    if rt
        .manifest
        .artifact(&format!("gate_fwd_w{workers}"))
        .is_none()
    {
        return;
    }
    let blocking = moe_trainer_params(rt.clone(), workers, false, false, false);
    // grad_overlap on, over both exchange schedules
    for overlap in [false, true] {
        let got = moe_trainer_params(rt.clone(), workers, true, overlap, false);
        for rank in 0..workers {
            for (i, (a, b)) in blocking[rank].iter().zip(&got[rank]).enumerate() {
                assert_eq!(
                    a, b,
                    "rank {rank} slot {i} (exchange overlap {overlap}): \
                     gate-grad overlap changed parameter bits"
                );
            }
        }
    }
    // ZeRO-sharded gate optimizer: same bits as the replicated path
    let zero = moe_trainer_params(rt.clone(), workers, false, false, true);
    for rank in 0..workers {
        for (i, (a, b)) in blocking[rank].iter().zip(&zero[rank]).enumerate() {
            assert_eq!(
                a, b,
                "rank {rank} slot {i}: ZeRO-sharded gate optimizer \
                 changed parameter bits"
            );
        }
    }
}

#[test]
fn overlapped_gate_sync_bit_identical_over_tcp_progress() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let workers = 2;
    if rt
        .manifest
        .artifact(&format!("gate_fwd_w{workers}"))
        .is_none()
    {
        return;
    }
    // thread-backend blocking reference vs tcp + progress + overlap-on
    let reference = moe_trainer_params(rt.clone(), workers, false, false);
    let joins: Vec<_> = (0..workers)
        .map(|rank| {
            let rt = rt.clone();
            std::thread::spawn(move || {
                let mut g = TcpGroup::connect_local(rank, workers, 47890).unwrap();
                g.enable_progress();
                let layer = MoeLayerBuilder::new()
                    .seed(3)
                    .overlap(true)
                    .chunks(2)
                    .grad_overlap(true)
                    .build(rt, workers, rank)
                    .unwrap();
                let mut tr = MoeLayerTrainer::new(layer, 1e-2);
                let mut counters = Counters::new();
                for step in 0..4 {
                    let mut x = TensorF32::zeros(&[tr.layer.nb, tr.layer.dm]);
                    Rng::new(50 + step * 7 + rank as u64).fill_normal(&mut x.data, 1.0);
                    tr.train_step(&mut g, x, &mut counters).unwrap();
                }
                g.barrier().unwrap();
                (
                    rank,
                    tr.layer
                        .params()
                        .into_iter()
                        .map(|(_, t)| t.data.clone())
                        .collect::<Vec<_>>(),
                )
            })
        })
        .collect();
    for j in joins {
        let (rank, params) = j.join().unwrap();
        for (i, (a, b)) in reference[rank].iter().zip(&params).enumerate() {
            assert_eq!(
                a, b,
                "rank {rank} slot {i}: tcp overlapped trainer diverged \
                 from the thread-backend blocking reference"
            );
        }
    }
}

#[test]
fn hier_topology_trainer_end_to_end() {
    // One hierarchical configuration end to end (PR 5): the
    // `MoeLayerTrainer` over a 2-node `TopoComm` — the layer's
    // exchanges route through the node leaders, the gate-grad sync
    // through the two-level tree.  Pinned two ways: hier blocking vs
    // hier grad-overlap is BITWISE identical (one shared tree
    // schedule), and hier vs the flat reference is element-close (the
    // documented reduction-order difference is the only divergence).
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let workers = 4;
    if rt
        .manifest
        .artifact(&format!("gate_fwd_w{workers}"))
        .is_none()
    {
        return;
    }
    let run_hier = |grad_overlap: bool| {
        let rt = rt.clone();
        run_workers(workers, move |h| {
            let comm_cfg = CommConfig {
                topology: "hier".into(),
                nodes: 2,
                ..CommConfig::default()
            };
            let mut h = TopoComm::new(h, comm_cfg.topology_for(workers)?)?;
            let layer = MoeLayerBuilder::new()
                .seed(3)
                .comm_config(&comm_cfg)
                .grad_overlap(grad_overlap)
                .build(rt.clone(), workers, h.rank())?;
            let mut tr = MoeLayerTrainer::new(layer, 1e-2);
            let mut counters = Counters::new();
            let mut losses = Vec::new();
            for step in 0..4 {
                let mut x = TensorF32::zeros(&[tr.layer.nb, tr.layer.dm]);
                Rng::new(50 + step * 7 + h.rank() as u64).fill_normal(&mut x.data, 1.0);
                let s = tr.train_step(&mut h, x, &mut counters)?;
                assert!(s.loss.is_finite(), "step {step}: non-finite loss");
                losses.push(s.loss);
            }
            Ok((
                losses,
                tr.layer
                    .params()
                    .into_iter()
                    .map(|(_, t)| t.data.clone())
                    .collect::<Vec<_>>(),
            ))
        })
        .unwrap()
    };
    let hier_blocking = run_hier(false);
    let hier_overlap = run_hier(true);
    for rank in 0..workers {
        for (i, (a, b)) in hier_blocking[rank].1.iter().zip(&hier_overlap[rank].1).enumerate()
        {
            assert_eq!(
                a, b,
                "rank {rank} slot {i}: hier grad-overlap changed parameter bits"
            );
        }
    }
    // flat reference (same seeds, same steps, workers = 4): only the
    // gate-grad reduction order differs, so parameters stay close
    let flat = moe_trainer_params(rt.clone(), workers, false, false);
    for rank in 0..workers {
        for (i, (a, b)) in flat[rank].iter().zip(&hier_blocking[rank].1).enumerate() {
            let scale =
                1e-3 + a.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let diff = a
                .iter()
                .zip(b)
                .fold(0.0f32, |m, (x, y)| m.max((x - y).abs()));
            assert!(
                diff < 2e-3 * scale,
                "rank {rank} slot {i}: hier diverged from flat by {diff}"
            );
        }
    }
}

#[test]
fn host_adam_path_equals_fused_path() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let model = "gpt_moe";
    let seed = 33;
    let steps = 3;

    // --- fused path ---
    let mut fused = Trainer::new(&rt, model, seed).unwrap();
    let vocab = fused.entry.config_usize("vocab").unwrap();
    let seq = fused.entry.config_usize("seq").unwrap();
    let batch = fused.entry.config_usize("batch").unwrap();
    let lr = 3e-4f32; // the preset lr used when lowering train_step
    let corpus = Corpus::synthetic(vocab, 100_000, 9);
    let mut it = BatchIter::new(&corpus, batch, seq, 21);
    let batches: Vec<_> = (0..steps).map(|_| it.next_batch()).collect();
    let mut fused_losses = Vec::new();
    for b in &batches {
        fused_losses.push(fused.train_step(b).unwrap().loss);
    }

    // --- distributed path, world size 1 (no sync effects) ---
    let rt2 = rt.clone();
    let batches2 = batches.clone();
    let (dist_losses, dist_params) = run_workers(1, move |mut h| {
        let mut tr = DistTrainer::new(&rt2, "gpt_moe", seed, 1, lr)?;
        let mut losses = Vec::new();
        for b in &batches2 {
            losses.push(tr.train_step(&mut h, b)?);
        }
        Ok((losses, tr.params))
    })
    .unwrap()
    .remove(0);

    for (s, (a, b)) in fused_losses.iter().zip(&dist_losses).enumerate() {
        assert!(
            (a - b).abs() < 1e-4 * (1.0 + a.abs()),
            "step {s}: fused loss {a} vs dist {b}"
        );
    }
    // parameters agree after `steps` updates
    for (i, (a, b)) in fused
        .params
        .tensors
        .iter()
        .zip(&dist_params.tensors)
        .enumerate()
    {
        let diff = ops::max_abs_diff(a, b).unwrap();
        let scale = 1e-3 + b.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(
            diff < 2e-3 * scale,
            "param {} (`{}`): diff {diff}",
            i,
            fused.params.entries[i].name
        );
    }
}

#[test]
fn multi_worker_training_decreases_loss_and_stays_in_sync() {
    let Some(rt) = runtime() else { return };
    let workers = 2;
    let out = run_workers(workers, {
        let rt = rt.clone();
        move |mut h| {
            let mut tr = DistTrainer::new(&rt, "gpt_moe", 77, workers, 1e-3)?;
            let vocab = tr.entry.config_usize("vocab").unwrap();
            let seq = tr.entry.config_usize("seq").unwrap();
            let batch = tr.entry.config_usize("batch").unwrap();
            let corpus = Corpus::synthetic(vocab, 100_000, 4);
            let mut it = BatchIter::shard(&corpus, batch, seq, 10, h.rank());
            let mut losses = Vec::new();
            for _ in 0..6 {
                losses.push(tr.train_step(&mut h, &it.next_batch())?);
            }
            Ok((losses, tr.params))
        }
    })
    .unwrap();

    let (l0, p0) = &out[0];
    let (l1, p1) = &out[1];
    // both workers report the identical global loss
    for (a, b) in l0.iter().zip(l1) {
        assert_eq!(a, b, "global loss must be identical on all workers");
    }
    assert!(l0.last().unwrap() < l0.first().unwrap(), "{l0:?}");
    // replicated parameters stay bit-identical across workers
    for (i, (a, b)) in p0.tensors.iter().zip(&p1.tensors).enumerate() {
        let diff = ops::max_abs_diff(a, b).unwrap();
        assert!(
            diff < 1e-6,
            "param {} (`{}`) diverged across workers: {diff}",
            i,
            p0.entries[i].name
        );
    }
}
