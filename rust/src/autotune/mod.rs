//! Online autotuning: α-β calibration + simulator-driven config search.
//!
//! Nine PRs of knobs (`overlap`, `chunks`, `chunk_policy`, `bucket_kb`,
//! `grad_overlap`, `grad_shard`, `topology`, …) outgrew hand-tuning —
//! the co-design burden the FastMoE paper says a well-tuned MoE system
//! must absorb *for* the operator.  This module closes the loop from
//! measured step counters back into the analytic cost model the benches
//! already trust ([`crate::sim::NetModel`]), in three layers:
//!
//! 1. **Calibration** ([`Calibrator`]): a few instrumented steps
//!    accumulate the scoped phase timers (`phase_dispatch_ns`,
//!    `phase_compute_ns`, `phase_combine_ns`, `phase_gradsync_ns`,
//!    `phase_opt_ns`) and byte counters (`moe_a2a_bytes`,
//!    `grad_sync_bytes`, `moe_copy_bytes`) over a window
//!    ([`crate::metrics::Counters::delta_since`], so lifetime totals
//!    never leak in), then fit a [`ModelFit`].  One operating point
//!    cannot separate α from β, so α (and `alpha_local`) stay **pinned
//!    to the IB-EDR preset** and β is fitted from the residual wire
//!    time; `beta_local` keeps the preset's local:inter ratio.  The
//!    fitted parameters are **rank-agreed** by an all-reduce mean, so
//!    every rank holds bit-identical numbers and tunes identically.
//! 2. **Search** ([`search`]): a pure, deterministic enumeration of the
//!    discrete config lattice — chunks ∈ {1, 2, 4, 8, 0 = adaptive} ×
//!    chunk_policy × bucket_kb ∈ {64 … 4096} × flat/hier ×
//!    overlap/grad_overlap/grad_shard, respecting the config-validation
//!    rules (`zero` excludes `grad_overlap`; hier needs a dividing
//!    local size) — scoring each candidate with the fitted model's
//!    `moe_step_*` + `grad_step_*` variants and returning the strict
//!    argmin as a typed [`TunedConfig`].  Fixed iteration order +
//!    strict `<` ⇒ the same fit picks the same config on every rank.
//! 3. **Execution** ([`Autotuner`]): the `[auto]` section
//!    ([`crate::config::AutoConfig`]) drives the per-step state machine
//!    the trainers call at each step boundary — calibrate, fit, search,
//!    then monitor the rank-agreed measured step time and re-open a
//!    calibration window when it drifts more than `retune_drift` from
//!    the prediction.  `apply = "report"` logs the winner as a
//!    pasteable `[comm]` snippet and changes nothing; `apply = "live"`
//!    hands back the step-boundary-safe knobs (`chunks`,
//!    `chunk_policy`, `bucket_kb`) for lockstep application, while
//!    restart-only knobs (`topology`, `grad_shard`, `overlap` flags)
//!    stay recommendations.
//!
//! The argmin ignores config-*independent* cost (gate GEMMs, host
//! copies — identical under every candidate), and the drift anchor
//! re-bases the model's predicted delta on the *measured* calibration
//! step time, so systematic model offsets cancel out of both decisions.

use crate::comm::Comm;
use crate::config::{AutoConfig, CommConfig};
use crate::error::{Error, Result};
use crate::metrics::Counters;
use crate::moe::ChunkPolicy;
use crate::sim::{NetModel, NetPreset};

/// Chunk counts the search scans under `overlap` (0 = adaptive, scored
/// as the count `moe::adaptive_chunks` would settle on; listed last so
/// a pinned count wins the tie against its adaptive equivalent).
pub const CHUNK_LATTICE: &[usize] = &[1, 2, 4, 8, 0];

/// Gradient-bucket sizes (KiB) the search scans under `grad_overlap`.
pub const BUCKET_KB_LATTICE: &[usize] = &[64, 128, 256, 512, 1024, 2048, 4096];

/// One point of the `[comm]` knob lattice — everything the search
/// ranks, in the trainers' own terms.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KnobState {
    /// Pipelined dispatch/compute/combine (`[comm] overlap`).
    pub overlap: bool,
    /// Exchange chunk count (`0` = adaptive).
    pub chunks: usize,
    /// Adaptive-chunk agreement policy.
    pub chunk_policy: ChunkPolicy,
    /// Bucketed nonblocking gradient sync (`[comm] grad_overlap`).
    pub grad_overlap: bool,
    /// ZeRO-sharded optimizer (`[comm] grad_shard = "zero"`).
    pub zero: bool,
    /// Gradient-bucket payload target, KiB.
    pub bucket_kb: usize,
    /// Hierarchical (node-aware) collectives (`[comm] topology`).
    pub hier: bool,
}

impl KnobState {
    /// Derive the current point from a validated [`CommConfig`].
    pub fn from_comm(cfg: &CommConfig) -> KnobState {
        KnobState {
            overlap: cfg.overlap,
            chunks: cfg.chunks,
            chunk_policy: ChunkPolicy::parse(&cfg.chunk_policy)
                .unwrap_or(ChunkPolicy::Mean),
            grad_overlap: cfg.grad_overlap,
            zero: cfg.grad_shard == "zero",
            bucket_kb: cfg.bucket_kb,
            hier: cfg.topology == "hier",
        }
    }

    /// Whether `other` shares this point's restart-only knobs — the
    /// ones live mode must not touch (they change the wire protocol or
    /// optimizer-state layout, not just the step-boundary schedule).
    pub fn same_restart_knobs(&self, other: &KnobState) -> bool {
        self.overlap == other.overlap
            && self.grad_overlap == other.grad_overlap
            && self.zero == other.zero
            && self.hier == other.hier
    }
}

/// The fitted model parameters plus the measured per-step operating
/// point they were fitted at — everything [`search`] needs, rank-agreed
/// so every rank holds identical bits.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelFit {
    /// Inter-node per-message latency, seconds (pinned to the preset:
    /// one operating point cannot separate α from β).
    pub alpha: f64,
    /// Fitted inter-node bandwidth, bytes/second.
    pub beta: f64,
    /// Intra-node latency, seconds (pinned to the preset).
    pub alpha_local: f64,
    /// Intra-node bandwidth — `beta` scaled by the preset's
    /// local:inter ratio.
    pub beta_local: f64,
    /// Host memcpy bandwidth (preset; staging copies are
    /// config-independent, so this never decides the argmin).
    pub host_beta: f64,
    /// Expert compute seconds per step (measured).
    pub compute: f64,
    /// Host optimiser seconds per step (measured).
    pub opt: f64,
    /// Gradient-sync wire seconds per step (measured; diagnostic — the
    /// grad tail is *scored* from `grad_bytes` and the fitted link).
    pub gradsync: f64,
    /// Exchange bytes per step (`moe_a2a_bytes`).
    pub a2a_bytes: f64,
    /// Synced gradient bytes per step (`grad_sync_bytes`).
    pub grad_bytes: f64,
    /// Host staging-copy bytes per step (`moe_copy_bytes`).
    pub copy_bytes: f64,
    /// Measured wall seconds per step — the drift anchor.
    pub step_time: f64,
    /// World size the window ran at.
    pub workers: usize,
    /// Ranks per node for the hier candidates (1 = hier not available).
    pub local_size: usize,
}

impl ModelFit {
    /// The preset every pinned parameter (and every unfittable one)
    /// falls back to.
    pub fn preset() -> NetModel {
        NetModel::preset(NetPreset::IbEdr)
    }

    /// Build the scoring model from the fitted parameters.
    pub fn net_model(&self) -> NetModel {
        NetModel {
            alpha: self.alpha,
            beta: self.beta,
            alpha_local: self.alpha_local,
            beta_local: self.beta_local,
            host_beta: self.host_beta,
            alloc_beta: Self::preset().alloc_beta,
            enabled: true,
        }
    }

    /// Fit from rank-agreed per-step measurements.  α is pinned; β is
    /// the bytes over the wire time *net of latency*, clamped to a sane
    /// band (1 MB/s … 10 TB/s) so a degenerate window (zero bytes, or a
    /// sub-latency wire time) falls back toward the preset instead of
    /// producing an absurd link.
    #[allow(clippy::too_many_arguments)]
    pub fn from_measurements(
        workers: usize,
        local_size: usize,
        step_time: f64,
        wire: f64,
        compute: f64,
        opt: f64,
        gradsync: f64,
        a2a_bytes: f64,
        grad_bytes: f64,
        copy_bytes: f64,
    ) -> ModelFit {
        let p = Self::preset();
        let alpha = p.alpha;
        let alpha_local = p.alpha_local;
        let wire_net = wire - alpha * workers.saturating_sub(1) as f64;
        let beta = if workers > 1 && a2a_bytes > 0.0 && wire_net > 1e-9 {
            (a2a_bytes / wire_net).clamp(1e6, 1e13)
        } else {
            p.beta
        };
        let beta_local = beta * (p.beta_local / p.beta);
        ModelFit {
            alpha,
            beta,
            alpha_local,
            beta_local,
            host_beta: p.host_beta,
            compute: compute.max(0.0),
            opt: opt.max(0.0),
            gradsync: gradsync.max(0.0),
            a2a_bytes: a2a_bytes.max(0.0),
            grad_bytes: grad_bytes.max(0.0),
            copy_bytes: copy_bytes.max(0.0),
            step_time: step_time.max(0.0),
            workers: workers.max(1),
            local_size: local_size.max(1),
        }
    }
}

/// Score one lattice point under a fit: the modelled MoE exchange +
/// compute phase, plus the gradient-sync tail (scored with zero compute
/// — the backward is already inside the MoE term, so the tail adds only
/// its wire and optimiser cost).  Pure; identical inputs give identical
/// bits on every rank.
pub fn score(fit: &ModelFit, k: &KnobState) -> f64 {
    let m = fit.net_model();
    let w = fit.workers;
    let l = if k.hier { fit.local_size } else { 1 };
    let ab = fit.a2a_bytes.round() as usize;
    let gb = fit.grad_bytes.round() as usize;
    let chunks = if k.chunks == 0 {
        // adaptive settles on the wire-fraction count (moe::adaptive_chunks)
        let wire = if k.hier {
            m.all_to_all_hier(w, l, ab)
        } else {
            m.all_to_all(w, ab)
        };
        crate::moe::adaptive_chunks(wire, fit.compute, w)
    } else {
        k.chunks.clamp(1, w.max(1))
    };
    let moe = match (k.hier, k.overlap) {
        (false, false) => m.moe_step_blocking(w, ab, fit.compute),
        (false, true) => m.moe_step_overlapped(w, ab, fit.compute, chunks),
        (true, false) => m.moe_step_blocking_hier(w, l, ab, fit.compute),
        (true, true) => m.moe_step_overlapped_hier(w, l, ab, fit.compute, chunks),
    };
    let grad = if k.zero {
        if k.hier {
            m.grad_step_zero_hier(w, l, gb, 0.0, fit.opt)
        } else {
            m.grad_step_zero(w, gb, 0.0, fit.opt)
        }
    } else if k.grad_overlap && w > 1 {
        // score the EXACT bucket count this bucket_kb yields (not the
        // best-B relaxation NetModel::grad_step_overlapped takes —
        // that would make every kb tie at the unconstrained optimum):
        // t(B) = ring(bytes/B) + opt/B + (B−1)·max(ring, opt/B)
        let b = (gb / (k.bucket_kb * 1024)).max(1);
        let ring = if k.hier {
            m.all_reduce_hier(w, l, gb / b)
        } else {
            m.all_reduce(w, gb / b)
        };
        let a = fit.opt / b as f64;
        ring + a + (b as f64 - 1.0) * ring.max(a)
    } else if k.hier {
        m.grad_step_blocking_hier(w, l, gb, 0.0, fit.opt)
    } else {
        m.grad_step_blocking(w, gb, 0.0, fit.opt)
    };
    moe + grad
}

/// The search result: a lattice point and its modelled step time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TunedConfig {
    pub knobs: KnobState,
    /// Ranks per node the hier knob refers to (1 when flat).
    pub local_size: usize,
    /// Modelled seconds per step at this point.
    pub predicted: f64,
}

impl TunedConfig {
    /// The chosen config as a pasteable `[comm]` TOML snippet — the
    /// exact spellings `ConfigFile::comm()` validates (round-tripped in
    /// the unit tests, so a recommendation can never be un-launchable).
    pub fn toml_snippet(&self) -> String {
        let mut s = String::from("[comm]\n");
        s.push_str(&format!("overlap = {}\n", self.knobs.overlap));
        s.push_str(&format!("chunks = {}\n", self.knobs.chunks));
        s.push_str(&format!(
            "chunk_policy = \"{}\"\n",
            self.knobs.chunk_policy.as_str()
        ));
        s.push_str(&format!("grad_overlap = {}\n", self.knobs.grad_overlap));
        s.push_str(&format!("bucket_kb = {}\n", self.knobs.bucket_kb));
        s.push_str(&format!(
            "grad_shard = \"{}\"\n",
            if self.knobs.zero { "zero" } else { "none" }
        ));
        s.push_str(&format!(
            "topology = \"{}\"\n",
            if self.knobs.hier { "hier" } else { "flat" }
        ));
        if self.knobs.hier {
            s.push_str(&format!("local_size = {}\n", self.local_size));
        }
        s
    }
}

/// Both answers one search produces: the global argmin (`best` — what a
/// fresh launch should use) and the argmin *within the current
/// restart-only knobs* (`live` — what live mode may apply at the next
/// step boundary without changing wire protocol or state layout).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TuneOutcome {
    pub best: TunedConfig,
    pub live: TunedConfig,
}

/// Enumerate the candidate lattice for a fit, in the fixed documented
/// order (current-config spellings lead their alternatives, so score
/// ties never churn a knob).  Knobs that cannot matter at a point
/// (chunks without `overlap`, bucket_kb without `grad_overlap`) keep
/// their current values instead of multiplying the lattice.
pub fn lattice(fit: &ModelFit, current: &KnobState) -> Vec<KnobState> {
    let w = fit.workers;
    let hier_ok = fit.local_size > 1 && w % fit.local_size == 0 && w > fit.local_size;
    let topos: &[bool] = if hier_ok { &[false, true] } else { &[false] };
    let policies: [ChunkPolicy; 2] = match current.chunk_policy {
        ChunkPolicy::Mean => [ChunkPolicy::Mean, ChunkPolicy::Max],
        ChunkPolicy::Max => [ChunkPolicy::Max, ChunkPolicy::Mean],
    };
    let mut out = Vec::new();
    for &hier in topos {
        for overlap in [false, true] {
            // chunk values clamp to the world and dedupe in order
            let mut chunk_opts: Vec<usize> = Vec::new();
            if overlap {
                for &c in CHUNK_LATTICE {
                    let c = if c == 0 { 0 } else { c.clamp(1, w.max(1)) };
                    if !chunk_opts.contains(&c) {
                        chunk_opts.push(c);
                    }
                }
            } else {
                chunk_opts.push(current.chunks);
            }
            for &chunks in &chunk_opts {
                let pols: &[ChunkPolicy] = if overlap && chunks == 0 {
                    &policies
                } else {
                    &policies[..1]
                };
                for &chunk_policy in pols {
                    // (grad_overlap, zero): "zero" excludes grad_overlap
                    // (the config validation rule, baked into the lattice)
                    for (grad_overlap, zero) in
                        [(false, false), (true, false), (false, true)]
                    {
                        let buckets: &[usize] = if grad_overlap {
                            BUCKET_KB_LATTICE
                        } else {
                            std::slice::from_ref(&current.bucket_kb)
                        };
                        for &bucket_kb in buckets {
                            out.push(KnobState {
                                overlap,
                                chunks,
                                chunk_policy,
                                grad_overlap,
                                zero,
                                bucket_kb,
                                hier,
                            });
                        }
                    }
                }
            }
        }
    }
    out
}

/// Deterministic argmin over the lattice.  Strict `<` comparison over a
/// fixed enumeration order means identical fits produce identical
/// [`TuneOutcome`]s on every rank — the rank-symmetry invariant the
/// equivalence suite pins on both backends.
pub fn search(fit: &ModelFit, current: &KnobState) -> TuneOutcome {
    let tuned = |k: KnobState| TunedConfig {
        knobs: k,
        local_size: if k.hier { fit.local_size } else { 1 },
        predicted: score(fit, &k),
    };
    let mut best = tuned(*current);
    let mut live = best;
    for k in lattice(fit, current) {
        let t = tuned(k);
        if t.predicted < best.predicted {
            best = t;
        }
        if k.same_restart_knobs(current) && t.predicted < live.predicted {
            live = t;
        }
    }
    TuneOutcome { best, live }
}

/// One calibration window: snapshots the counters at open, accumulates
/// wall time per step, and at close fits a rank-agreed [`ModelFit`]
/// from the window *delta* (never the lifetime totals).
pub struct Calibrator {
    workers: usize,
    local_size: usize,
    start: Counters,
    steps: usize,
    wall: f64,
}

impl Calibrator {
    /// Open a window over `counters` as they stand right now.
    pub fn begin(counters: &Counters, workers: usize, local_size: usize) -> Calibrator {
        Calibrator {
            workers: workers.max(1),
            local_size: local_size.max(1),
            start: counters.snapshot(),
            steps: 0,
            wall: 0.0,
        }
    }

    /// Record one completed step's wall time.
    pub fn record_step(&mut self, secs: f64) {
        self.steps += 1;
        self.wall += secs.max(0.0);
    }

    /// Steps recorded so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Close the window: difference the counters, normalise per step,
    /// **rank-agree** the raw measurements (all-reduce mean — every
    /// rank contributes its local view and every rank derives the same
    /// bits), and fit.  The agreement is an ordinary world collective,
    /// so it composes with the trainers' lockstep like any other.
    pub fn finish(
        &self,
        comm: &mut impl Comm,
        counters: &Counters,
    ) -> Result<ModelFit> {
        if self.steps == 0 {
            return Err(Error::Config(
                "autotune: calibration window closed with zero steps".into(),
            ));
        }
        let d = counters.delta_since(&self.start);
        let ns = |name: &str| d.get(name) as f64 / 1e9;
        let per = 1.0 / self.steps as f64;
        // raw per-step measurements, this rank's view
        let mut v: Vec<f32> = vec![
            (self.wall * per) as f32,
            ((ns("phase_dispatch_ns") + ns("phase_combine_ns")) * per) as f32,
            (ns("phase_compute_ns") * per) as f32,
            (ns("phase_opt_ns") * per) as f32,
            (ns("phase_gradsync_ns") * per) as f32,
            (d.get("moe_a2a_bytes") as f64 * per) as f32,
            (d.get("grad_sync_bytes") as f64 * per) as f32,
            (d.get("moe_copy_bytes") as f64 * per) as f32,
        ];
        if comm.size() > 1 {
            comm.all_reduce_sum(&mut v)?;
            let inv = 1.0 / comm.size() as f32;
            for x in v.iter_mut() {
                *x *= inv;
            }
        }
        Ok(ModelFit::from_measurements(
            self.workers,
            self.local_size,
            v[0] as f64,
            v[1] as f64,
            v[2] as f64,
            v[3] as f64,
            v[4] as f64,
            v[5] as f64,
            v[6] as f64,
            v[7] as f64,
        ))
    }
}

/// The per-step state machine the trainers drive at step boundaries:
/// calibrate → fit + search → monitor drift → re-calibrate.  All
/// decisions derive from rank-agreed data only (the fit and the
/// monitored mean step time both cross an all-reduce), so every rank
/// transitions identically — the lockstep invariant that makes live
/// application safe.
pub struct Autotuner {
    cfg: AutoConfig,
    workers: usize,
    local_size: usize,
    /// The knobs currently *running* (updated by live application).
    current: KnobState,
    /// The knobs the last calibration window ran under.
    calib_knobs: KnobState,
    /// Last fit (rank-identical).
    pub fit: Option<ModelFit>,
    /// Last search result (rank-identical).
    pub outcome: Option<TuneOutcome>,
    /// Open calibration window, if any.
    cal: Option<Calibrator>,
    window_steps: usize,
    window_wall: f64,
    /// How many drift-triggered re-calibrations have fired.
    pub retunes: u64,
}

impl Autotuner {
    /// Build from the `[auto]` section and the validated `[comm]`
    /// config the run launched with.
    pub fn new(cfg: AutoConfig, comm_cfg: &CommConfig, workers: usize) -> Result<Autotuner> {
        let current = KnobState::from_comm(comm_cfg);
        let local_size = if current.hier {
            comm_cfg.topology_for(workers)?.local_size()
        } else if comm_cfg.local_size > 1 && workers % comm_cfg.local_size == 0 {
            // flat run on a known node layout: hier is a *candidate*
            comm_cfg.local_size
        } else {
            1
        };
        Ok(Autotuner {
            cfg,
            workers: workers.max(1),
            local_size,
            current,
            calib_knobs: current,
            fit: None,
            outcome: None,
            cal: None,
            window_steps: 0,
            window_wall: 0.0,
            retunes: 0,
        })
    }

    /// Whether live application is configured (`apply = "live"`).
    pub fn live(&self) -> bool {
        self.cfg.apply == "live"
    }

    /// The knobs the tuner believes are running.
    pub fn current(&self) -> &KnobState {
        &self.current
    }

    /// Live mode applied `knobs` at a step boundary: re-base the drift
    /// anchor on the new point.
    pub fn note_applied(&mut self, knobs: KnobState) {
        self.current = knobs;
    }

    /// The drift anchor: the calibration window's *measured* step time,
    /// re-based by the modelled delta if the running knobs have changed
    /// since — systematic model offsets (gate GEMMs, host copies)
    /// cancel out of the subtraction.
    fn anchor(&self) -> Option<f64> {
        let fit = self.fit.as_ref()?;
        Some(fit.step_time - score(fit, &self.calib_knobs) + score(fit, &self.current))
    }

    /// Observe one completed step (`secs` wall time, `counters` as the
    /// trainer's step counters stand now).  Returns a fresh
    /// [`TuneOutcome`] exactly when a calibration window just closed —
    /// the caller reports it and, in live mode, applies
    /// `outcome.live.knobs` then calls [`Autotuner::note_applied`].
    pub fn observe(
        &mut self,
        comm: &mut impl Comm,
        counters: &Counters,
        secs: f64,
    ) -> Result<Option<TuneOutcome>> {
        if !self.cfg.enabled {
            return Ok(None);
        }
        if self.cal.is_none() && self.fit.is_none() {
            // first observed step opens the initial window; this step's
            // counters are already in the snapshot base, so the window
            // covers the *next* calib_steps steps exactly
            self.calib_knobs = self.current;
            self.cal =
                Some(Calibrator::begin(counters, self.workers, self.local_size));
            return Ok(None);
        }
        if let Some(cal) = self.cal.as_mut() {
            cal.record_step(secs);
            if cal.steps() < self.cfg.calib_steps {
                return Ok(None);
            }
            let fit = cal.finish(comm, counters)?;
            let outcome = search(&fit, &self.current);
            self.fit = Some(fit);
            self.outcome = Some(outcome);
            self.cal = None;
            self.window_steps = 0;
            self.window_wall = 0.0;
            return Ok(Some(outcome));
        }
        // monitoring: accumulate, and at each window boundary agree the
        // mean measured step time and test it against the anchor
        self.window_steps += 1;
        self.window_wall += secs.max(0.0);
        if self.window_steps < self.cfg.calib_steps {
            return Ok(None);
        }
        let mut v = [(self.window_wall / self.window_steps as f64) as f32];
        if comm.size() > 1 {
            comm.all_reduce_sum(&mut v)?;
            v[0] /= comm.size() as f32;
        }
        let measured = v[0] as f64;
        self.window_steps = 0;
        self.window_wall = 0.0;
        if let Some(anchor) = self.anchor() {
            if anchor > 0.0
                && ((measured - anchor).abs() / anchor) > self.cfg.retune_drift
            {
                self.retunes += 1;
                self.calib_knobs = self.current;
                self.cal =
                    Some(Calibrator::begin(counters, self.workers, self.local_size));
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::run_workers;
    use crate::config::ConfigFile;

    fn synthetic_fit(beta: f64, compute: f64, opt: f64, workers: usize) -> ModelFit {
        let p = ModelFit::preset();
        ModelFit {
            alpha: p.alpha,
            beta,
            alpha_local: p.alpha_local,
            beta_local: beta * (p.beta_local / p.beta),
            host_beta: p.host_beta,
            compute,
            opt,
            gradsync: 0.0,
            a2a_bytes: 8.0 * (1 << 20) as f64,
            grad_bytes: 4.0 * (1 << 20) as f64,
            copy_bytes: 0.0,
            step_time: 2e-3,
            workers,
            local_size: 2,
        }
    }

    fn default_knobs() -> KnobState {
        KnobState::from_comm(&CommConfig::default())
    }

    #[test]
    fn search_is_deterministic() {
        let fit = synthetic_fit(12.5e9, 1e-3, 2e-4, 8);
        let current = default_knobs();
        let first = search(&fit, &current);
        for _ in 0..50 {
            let again = search(&fit, &current);
            assert_eq!(first, again, "same fit must give the same config");
            assert_eq!(
                first.best.predicted.to_bits(),
                again.best.predicted.to_bits(),
                "prediction must be bit-identical"
            );
        }
        // the snippet is deterministic too
        assert_eq!(first.best.toml_snippet(), search(&fit, &current).best.toml_snippet());
    }

    #[test]
    fn search_prefers_overlap_when_wire_matches_compute() {
        // wire ≈ compute: the pipelined step strictly beats blocking,
        // so the argmin must turn overlap on with > 1 chunk
        let fit = synthetic_fit(12.5e9, 1e-3, 1e-4, 8);
        let wire = fit.net_model().all_to_all(8, fit.a2a_bytes as usize);
        assert!(wire > 1e-4 && wire < 1e-2, "operating point sanity: {wire}");
        let out = search(&fit, &default_knobs());
        assert!(out.best.knobs.overlap, "overlap must win: {:?}", out.best);
        let c = out.best.knobs.chunks;
        assert!(c == 0 || c > 1, "expected multi-chunk or adaptive, got {c}");
        // and the prediction really is the score of the chosen point
        assert_eq!(out.best.predicted, score(&fit, &out.best.knobs));
    }

    #[test]
    fn search_prefers_zero_when_optimiser_dominates() {
        // a huge host-optimiser term: ZeRO's opt/n shard beats both the
        // blocking tail and any bucket pipeline (which can only hide
        // opt behind wire, not shrink it)
        let fit = synthetic_fit(12.5e9, 1e-4, 50e-3, 8);
        let out = search(&fit, &default_knobs());
        assert!(out.best.knobs.zero, "zero must win: {:?}", out.best);
        assert!(!out.best.knobs.grad_overlap, "zero excludes grad_overlap");
    }

    #[test]
    fn live_respects_restart_only_knobs() {
        let fit = synthetic_fit(12.5e9, 1e-3, 50e-3, 8);
        let current = default_knobs(); // flat, no overlap, no grad_overlap
        let out = search(&fit, &current);
        // the live point may only move chunks / chunk_policy / bucket_kb
        assert!(out.live.knobs.same_restart_knobs(&current), "{:?}", out.live);
        // the global best here flips restart-only knobs (zero), so live
        // must be the *constrained* optimum, not the global one
        assert!(out.best.knobs.zero);
        assert!(!out.live.knobs.zero);
        assert!(out.live.predicted >= out.best.predicted);
        // and live never scores worse than simply keeping the current
        // config (current is in the constrained set)
        assert!(out.live.predicted <= score(&fit, &current));
    }

    #[test]
    fn every_candidate_snippet_round_trips_validation() {
        // the lattice bakes in the config rules (zero ⊻ grad_overlap,
        // hier spelling, policy names) — prove it by round-tripping
        // EVERY candidate's snippet through the real validator
        let fit = synthetic_fit(12.5e9, 1e-3, 1e-3, 8);
        let current = default_knobs();
        let cands = lattice(&fit, &current);
        assert!(cands.len() > 50, "lattice too small: {}", cands.len());
        assert!(cands.iter().any(|k| k.hier), "hier candidates missing");
        assert!(cands.iter().any(|k| k.zero), "zero candidates missing");
        for k in cands {
            let t = TunedConfig {
                knobs: k,
                local_size: if k.hier { fit.local_size } else { 1 },
                predicted: 0.0,
            };
            let cfg = ConfigFile::parse(&t.toml_snippet())
                .unwrap_or_else(|e| panic!("snippet parse {k:?}: {e}"))
                .comm()
                .unwrap_or_else(|e| panic!("snippet validate {k:?}: {e}"));
            assert_eq!(cfg.overlap, k.overlap);
            assert_eq!(cfg.chunks, k.chunks);
            assert_eq!(cfg.chunk_policy, k.chunk_policy.as_str());
            assert_eq!(cfg.grad_overlap, k.grad_overlap);
            assert_eq!(cfg.bucket_kb, k.bucket_kb);
            assert_eq!(cfg.grad_shard, if k.zero { "zero" } else { "none" });
            assert_eq!(cfg.topology, if k.hier { "hier" } else { "flat" });
            if k.hier {
                // the snippet pins the node split it was scored under
                let topo = cfg.topology_for(fit.workers).unwrap();
                assert_eq!(topo.local_size(), fit.local_size);
            }
        }
    }

    #[test]
    fn hier_candidates_gated_by_divisibility() {
        let mut fit = synthetic_fit(12.5e9, 1e-3, 1e-3, 8);
        fit.local_size = 3; // 8 % 3 ≠ 0
        assert!(lattice(&fit, &default_knobs()).iter().all(|k| !k.hier));
        fit.local_size = 1; // flat-only world
        assert!(lattice(&fit, &default_knobs()).iter().all(|k| !k.hier));
    }

    #[test]
    fn calibrator_windows_use_deltas_and_agree_across_ranks() {
        // Each rank measures a DIFFERENT operating point; the fits must
        // come out rank-identical (all-reduce mean) and reflect only
        // the window delta, not pre-window history.
        let fits = run_workers(4, |mut h| {
            let r = h.rank();
            let mut c = Counters::new();
            // pre-window noise that must NOT leak into the fit
            c.add("moe_a2a_bytes", 999_999_999);
            c.add("phase_dispatch_ns", 777_777_777);
            let mut cal = Calibrator::begin(&c, 4, 2);
            for _ in 0..4 {
                // per-rank skew around a 1 GB/s link at 1 MiB/step
                c.add("moe_a2a_bytes", (1 << 20) + r as u64 * 1024);
                c.add("phase_dispatch_ns", 1_000_000 + r as u64 * 10_000);
                c.add("phase_compute_ns", 2_000_000);
                c.add("phase_opt_ns", 500_000);
                c.add("grad_sync_bytes", 256 * 1024);
                cal.record_step(3.5e-3);
            }
            cal.finish(&mut h, &c)
        })
        .unwrap();
        for f in &fits[1..] {
            assert_eq!(f, &fits[0], "fit must be rank-identical");
            assert_eq!(f.beta.to_bits(), fits[0].beta.to_bits());
        }
        let f = &fits[0];
        // delta, not lifetime: ~1 MiB/step, not ~1 GB
        assert!(
            f.a2a_bytes > 1e6 && f.a2a_bytes < 2e6,
            "window leaked history: {} bytes/step",
            f.a2a_bytes
        );
        // fitted link ≈ bytes / (wire − α(w−1)) ≈ 1 GiB/s
        assert!(
            f.beta > 0.5e9 && f.beta < 2e9,
            "beta fit off: {:.3e} B/s",
            f.beta
        );
        assert!((f.compute - 2e-3).abs() < 1e-4, "compute {}", f.compute);
        assert!((f.opt - 5e-4).abs() < 1e-4, "opt {}", f.opt);
        assert!((f.step_time - 3.5e-3).abs() < 1e-5);
        // and the search over the agreed fit is identical everywhere
        let outs: Vec<TuneOutcome> =
            fits.iter().map(|f| search(f, &default_knobs())).collect();
        for o in &outs[1..] {
            assert_eq!(o, &outs[0]);
        }
    }

    #[test]
    fn degenerate_window_falls_back_to_preset_link() {
        // zero traffic (single worker, nothing measured): the fit must
        // come out at the preset, not a division blow-up
        let fits = run_workers(1, |mut h| {
            let c = Counters::new();
            let mut cal = Calibrator::begin(&c, 1, 1);
            cal.record_step(1e-3);
            cal.finish(&mut h, &c)
        })
        .unwrap();
        let p = ModelFit::preset();
        assert_eq!(fits[0].beta, p.beta);
        assert_eq!(fits[0].alpha, p.alpha);
        // zero-step window is an error, not a NaN fit
        let mut h_err = None;
        run_workers(1, |mut h| {
            let c = Counters::new();
            let cal = Calibrator::begin(&c, 1, 1);
            Ok(cal.finish(&mut h, &c).is_err())
        })
        .unwrap()
        .into_iter()
        .for_each(|e| h_err = Some(e));
        assert_eq!(h_err, Some(true));
    }

    #[test]
    fn autotuner_calibrates_monitors_and_retunes_on_drift() {
        let outcomes = run_workers(2, |mut h| {
            let auto = AutoConfig {
                enabled: true,
                calib_steps: 3,
                retune_drift: 0.25,
                apply: "report".into(),
            };
            let mut tuner = Autotuner::new(auto, &CommConfig::default(), 2)?;
            let mut c = Counters::new();
            let fed = |c: &mut Counters| {
                c.add("moe_a2a_bytes", 1 << 20);
                c.add("phase_dispatch_ns", 1_000_000);
                c.add("phase_compute_ns", 1_000_000);
            };
            let mut first = None;
            // steps 1..=4: open (1) + calibrate (2–4) → outcome at 4
            for step in 1..=4 {
                fed(&mut c);
                let got = tuner.observe(&mut h, &c, 2e-3)?;
                if got.is_some() {
                    assert_eq!(step, 4, "outcome must land at window close");
                    first = got;
                }
            }
            let first = first.expect("calibration must produce an outcome");
            assert!(tuner.fit.is_some());
            assert_eq!(tuner.retunes, 0);
            // steady monitoring at the calibrated step time: no retune
            for _ in 0..6 {
                fed(&mut c);
                assert!(tuner.observe(&mut h, &c, 2e-3)?.is_none());
            }
            assert_eq!(tuner.retunes, 0, "steady state must not retune");
            // a 5× slowdown blows the 25% drift budget → window reopens
            // and the NEXT window close yields a fresh outcome
            let mut retuned = None;
            for _ in 0..12 {
                fed(&mut c);
                if let Some(o) = tuner.observe(&mut h, &c, 10e-3)? {
                    retuned = Some(o);
                    break;
                }
            }
            assert!(retuned.is_some(), "drift must force a re-tune");
            assert_eq!(tuner.retunes, 1);
            Ok((first, retuned.unwrap()))
        })
        .unwrap();
        // both ranks saw identical outcomes at both tunes
        assert_eq!(outcomes[0], outcomes[1]);
    }

    #[test]
    fn disabled_autotuner_is_inert() {
        run_workers(1, |mut h| {
            let mut tuner =
                Autotuner::new(AutoConfig::default(), &CommConfig::default(), 1)?;
            let c = Counters::new();
            for _ in 0..20 {
                assert!(tuner.observe(&mut h, &c, 1e-3)?.is_none());
            }
            assert!(tuner.fit.is_none() && tuner.outcome.is_none());
            Ok(())
        })
        .unwrap();
    }
}
