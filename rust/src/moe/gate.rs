//! Pluggable gating policies — the top of the paper's §3.1 hierarchy.
//!
//! The dispatch substrate (`DispatchPlan`, `ExpertBatch`, the Figure-2
//! exchange) is fixed and high-performance; *which* experts a token
//! visits and with what weight is a swappable policy behind the
//! [`Gate`] trait.  Three gates ship with the system:
//!
//! * [`TopKSoftmaxGate`] — the seed behaviour: top-k selection + k-way
//!   softmax over the selected raw scores.  Bit-identical to the free
//!   functions [`topk_softmax`](super::topk_softmax) /
//!   [`topk_softmax_bwd`](super::topk_softmax_bwd) it delegates to.
//! * [`SwitchGate`] — Switch-Transformer top-1 routing with a capacity
//!   factor: each token goes to its argmax expert weighted by the full
//!   softmax probability; tokens over an expert's capacity are
//!   *dropped* by zero-weighting their assignment.  Because every
//!   assignment slot is still emitted (filler slots carry weight 0),
//!   `DispatchPlan` and the combine kernel need no shape changes.
//! * [`NoisyTopKGate`] — Shazeer-style noisy top-k: seeded Gaussian
//!   noise (via [`crate::rng`]) is added to the scores before top-k
//!   selection, so routing is exploratory yet exactly reproducible
//!   from a seed.
//!
//! All gates operate on the *host* side over the `[nb, n_e]` score
//! matrix the gate GEMM produced; the GEMM itself (scores = x·wg + bg)
//! stays inside the layer's HLO artifact.  Every shipped gate also
//! publishes the full row-softmax in `GateAssign::probs` to fund the
//! per-step balance-loss metric *and* the [`Gate::balance_grad`]
//! default, which backpropagates `moe.balance_coef ×` the GShard loss
//! into the gate GEMM — an O(nb·n_e) host pass, `d_model`× cheaper
//! than the gate GEMM that precedes it (routing `idx`/`w` stay
//! bit-identical either way).

use std::sync::atomic::{AtomicU64, Ordering};

use super::{topk_softmax, topk_softmax_bwd, GateAssign};
use crate::config::MoeConfig;
use crate::error::{Error, Result};
use crate::rng::Rng;
use crate::tensor::{ops, TensorF32};

/// A routing policy over gate scores.
///
/// `k` is the *slot width* of the dispatch substrate (fixed by the
/// compiled combine artifact): every gate must emit exactly `nb * k`
/// assignments.  Gates that logically route to fewer experts (e.g.
/// top-1 [`SwitchGate`]) pad with zero-weight filler slots.
pub trait Gate: Send + Sync {
    /// Short name for logs and config round-trips.
    fn name(&self) -> &'static str;

    /// Route one batch of scores `[nb, n_e]` into `nb * k` assignments.
    fn route(&self, scores: &TensorF32, k: usize) -> Result<GateAssign>;

    /// Backward of [`Gate::route`]: scatter the cotangent of the
    /// assignment weights `dw: [nb * k]` into a full `[nb, n_e]`
    /// score-gradient matrix.
    fn route_bwd(&self, assign: &GateAssign, dw: &[f32], ne: usize) -> Result<TensorF32>;

    /// Add the auxiliary balance-loss gradient
    /// `coef · d(balance_loss)/d(scores)` into `dscores`, given the
    /// iteration's per-expert *kept* counts.
    ///
    /// The GShard loss (see [`super::balance_loss`]) is
    /// `L = n_e · Σ_e f_e · p̄_e` with `f_e = counts_e / Σ counts`
    /// treated as a constant (the routing fraction is
    /// non-differentiable) and `p̄_e` the batch-mean softmax
    /// probability.  Differentiating through the row softmax:
    ///
    /// ```text
    /// ∂L/∂s_ij = p_ij · (g_j − Σ_e g_e · p_ie),   g_e = n_e · f_e / nb
    /// ```
    ///
    /// so descent drains probability from overloaded experts.  The
    /// default covers every gate that records `GateAssign::probs`; a
    /// gate without full probabilities inherits a no-op, as does
    /// `coef == 0` (reachable via `balance_coef = 0`, which preserves
    /// pre-wiring runs bit-for-bit; the config default is `0.01`).
    fn balance_grad(
        &self,
        assign: &GateAssign,
        counts: &[u32],
        coef: f32,
        dscores: &mut TensorF32,
    ) {
        if coef == 0.0 {
            return;
        }
        let Some(probs) = &assign.probs else { return };
        let Ok((nb, ne)) = probs.dims2() else { return };
        if counts.len() != ne || dscores.shape != probs.shape || nb == 0 {
            return;
        }
        let total: u64 = counts.iter().map(|&c| c as u64).sum();
        if total == 0 {
            return;
        }
        let g: Vec<f32> = counts
            .iter()
            .map(|&c| ne as f32 * (c as f64 / total as f64) as f32 / nb as f32)
            .collect();
        for i in 0..nb {
            let row = &probs.data[i * ne..(i + 1) * ne];
            let dot: f32 = row.iter().zip(&g).map(|(p, ge)| p * ge).sum();
            for j in 0..ne {
                dscores.data[i * ne + j] += coef * row[j] * (g[j] - dot);
            }
        }
    }
}

/// Construct a gate from the `[moe]` config section.
///
/// `seed` feeds the noise stream of [`NoisyTopKGate`] only; the other
/// gates are deterministic functions of the scores.
pub fn from_config(cfg: &MoeConfig, seed: u64) -> Result<Box<dyn Gate>> {
    match cfg.gate.as_str() {
        "topk" => Ok(Box::new(TopKSoftmaxGate)),
        "switch" => Ok(Box::new(SwitchGate::new(cfg.capacity_factor as f32)?)),
        "noisy_topk" => Ok(Box::new(NoisyTopKGate::new(
            cfg.noise_std as f32,
            seed ^ 0x901e,
        )?)),
        other => Err(Error::Config(format!(
            "unknown gate `{other}` (expected topk | switch | noisy_topk)"
        ))),
    }
}

/// Full row-softmax of a score matrix (the balance-loss probabilities).
fn full_softmax(scores: &TensorF32) -> Result<TensorF32> {
    let mut p = scores.clone();
    ops::softmax_rows(&mut p)?;
    Ok(p)
}

// ---------------------------------------------------------------------
// TopKSoftmaxGate
// ---------------------------------------------------------------------

/// The seed gate: top-k selection, k-way softmax over selected scores.
#[derive(Clone, Copy, Debug, Default)]
pub struct TopKSoftmaxGate;

impl Gate for TopKSoftmaxGate {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn route(&self, scores: &TensorF32, k: usize) -> Result<GateAssign> {
        let mut assign = topk_softmax(scores, k)?;
        // idx/w above are bit-identical to the seed path; probs only
        // feed the balance-loss monitor.
        assign.probs = Some(full_softmax(scores)?);
        Ok(assign)
    }

    fn route_bwd(&self, assign: &GateAssign, dw: &[f32], ne: usize) -> Result<TensorF32> {
        topk_softmax_bwd(assign, dw, ne)
    }
}

// ---------------------------------------------------------------------
// SwitchGate
// ---------------------------------------------------------------------

/// Switch-Transformer top-1 gate with capacity factor and token drop.
///
/// Per row: `w = softmax(scores)[argmax]` if the argmax expert still
/// has capacity, else `0` (the token is dropped — it still transits
/// the exchange, weighted to zero, so no shapes change).  Slots
/// `1..k` are filler assignments (next-ranked experts, weight 0).
///
/// Capacity is `ceil(capacity_factor * nb / n_e)` tokens per expert,
/// counted over this worker's own routing decisions, greedily in
/// token order (the Switch paper's policy).
#[derive(Clone, Copy, Debug)]
pub struct SwitchGate {
    pub capacity_factor: f32,
}

impl SwitchGate {
    pub fn new(capacity_factor: f32) -> Result<SwitchGate> {
        if !capacity_factor.is_finite() || capacity_factor <= 0.0 {
            return Err(Error::Config(format!(
                "switch gate needs capacity_factor > 0, got {capacity_factor}"
            )));
        }
        Ok(SwitchGate { capacity_factor })
    }

    /// Max tokens one expert accepts from a batch of `nb` rows.
    pub fn capacity(&self, nb: usize, ne: usize) -> usize {
        ((self.capacity_factor as f64 * nb as f64 / ne as f64).ceil() as usize).max(1)
    }
}

impl Gate for SwitchGate {
    fn name(&self) -> &'static str {
        "switch"
    }

    fn route(&self, scores: &TensorF32, k: usize) -> Result<GateAssign> {
        let (nb, ne) = scores.dims2()?;
        if k == 0 || k > ne {
            return Err(Error::Shape(format!("switch gate: {k} slots, {ne} experts")));
        }
        let probs = full_softmax(scores)?;
        let cap = self.capacity(nb, ne);
        let mut load = vec![0usize; ne];
        let mut idx = Vec::with_capacity(nb * k);
        let mut w = Vec::with_capacity(nb * k);
        for i in 0..nb {
            let top = ops::topk_indices(scores.row(i), k);
            let e = top[0];
            if load[e] < cap {
                load[e] += 1;
                w.push(probs.data[i * ne + e]);
            } else {
                w.push(0.0); // dropped: zero contribution to the combine
            }
            idx.push(e as u32);
            for &f in &top[1..] {
                idx.push(f as u32); // filler slots keep the nb*k shape
                w.push(0.0);
            }
        }
        Ok(GateAssign { nb, k, idx, w, probs: Some(probs) })
    }

    fn route_bwd(&self, assign: &GateAssign, dw: &[f32], ne: usize) -> Result<TensorF32> {
        if dw.len() != assign.nb * assign.k {
            return Err(Error::Shape("dw arity".into()));
        }
        let probs = assign
            .probs
            .as_ref()
            .ok_or_else(|| Error::Shape("switch bwd: assignment lacks probs".into()))?;
        let mut ds = TensorF32::zeros(&[assign.nb, ne]);
        for i in 0..assign.nb {
            let a = i * assign.k; // only slot 0 carries weight
            if assign.w[a] == 0.0 {
                continue; // dropped (or filler): w constant 0 ⇒ no grad
            }
            let e = assign.idx[a] as usize;
            let p_e = probs.data[i * ne + e];
            let d = dw[a];
            // w = softmax(s)_e  ⇒  dw/ds_j = p_e (δ_je − p_j)
            for j in 0..ne {
                let p_j = probs.data[i * ne + j];
                let delta = if j == e { 1.0 } else { 0.0 };
                ds.data[i * ne + j] = d * p_e * (delta - p_j);
            }
        }
        Ok(ds)
    }
}

// ---------------------------------------------------------------------
// NoisyTopKGate
// ---------------------------------------------------------------------

/// Noisy top-k: Gaussian noise on the scores before top-k selection.
///
/// The noise stream is derived from `(seed, call_counter)`, so a run
/// is exactly reproducible from its seed while every iteration still
/// sees fresh noise.  The noise is an additive constant w.r.t. the
/// scores, so the backward pass is the plain top-k softmax Jacobian
/// at the noisy operating point.
#[derive(Debug)]
pub struct NoisyTopKGate {
    pub noise_std: f32,
    seed: u64,
    calls: AtomicU64,
}

impl NoisyTopKGate {
    pub fn new(noise_std: f32, seed: u64) -> Result<NoisyTopKGate> {
        if !noise_std.is_finite() || noise_std < 0.0 {
            return Err(Error::Config(format!(
                "noisy_topk gate needs noise_std >= 0, got {noise_std}"
            )));
        }
        Ok(NoisyTopKGate { noise_std, seed, calls: AtomicU64::new(0) })
    }
}

impl Gate for NoisyTopKGate {
    fn name(&self) -> &'static str {
        "noisy_topk"
    }

    fn route(&self, scores: &TensorF32, k: usize) -> Result<GateAssign> {
        let call = self.calls.fetch_add(1, Ordering::Relaxed);
        let mut noisy = scores.clone();
        if self.noise_std > 0.0 {
            let mut rng = Rng::new(self.seed ^ call.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            for v in noisy.data.iter_mut() {
                *v += rng.normal() as f32 * self.noise_std;
            }
        }
        let mut assign = topk_softmax(&noisy, k)?;
        assign.probs = Some(full_softmax(&noisy)?);
        Ok(assign)
    }

    fn route_bwd(&self, assign: &GateAssign, dw: &[f32], ne: usize) -> Result<TensorF32> {
        // d(score + noise)/d(score) = 1: the seed Jacobian applies as-is.
        topk_softmax_bwd(assign, dw, ne)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scores(nb: usize, ne: usize, seed: u64) -> TensorF32 {
        let mut t = TensorF32::zeros(&[nb, ne]);
        Rng::new(seed).fill_normal(&mut t.data, 1.0);
        t
    }

    #[test]
    fn topk_gate_matches_free_function_exactly() {
        for seed in [1u64, 7, 99] {
            let s = scores(32, 8, seed);
            for k in [1usize, 2, 3] {
                let want = topk_softmax(&s, k).unwrap();
                let got = TopKSoftmaxGate.route(&s, k).unwrap();
                assert_eq!(got.idx, want.idx, "seed {seed} k {k}: expert ids");
                assert_eq!(got.w, want.w, "seed {seed} k {k}: weights (bitwise)");
                // and the Jacobian path is the identical code
                let dw: Vec<f32> = (0..32 * k).map(|i| (i as f32).sin()).collect();
                let a = TopKSoftmaxGate.route_bwd(&got, &dw, 8).unwrap();
                let b = topk_softmax_bwd(&want, &dw, 8).unwrap();
                assert_eq!(a.data, b.data);
            }
        }
    }

    #[test]
    fn switch_gate_respects_capacity_and_zero_weights_drops() {
        let (nb, ne, k) = (64, 4, 2);
        let s = scores(nb, ne, 3);
        let gate = SwitchGate::new(0.5).unwrap(); // tight: forces drops
        let cap = gate.capacity(nb, ne);
        let a = gate.route(&s, k).unwrap();
        assert_eq!(a.idx.len(), nb * k);
        let mut kept = vec![0usize; ne];
        let mut dropped = 0usize;
        for i in 0..nb {
            // slot 0 is the routed expert; slots 1.. are zero-weight filler
            for j in 1..k {
                assert_eq!(a.w[i * k + j], 0.0, "filler slot must be zero-weight");
            }
            let w0 = a.w[i * k];
            let e0 = a.idx[i * k] as usize;
            assert!(e0 < ne);
            if w0 == 0.0 {
                dropped += 1;
            } else {
                assert!(w0 > 0.0 && w0 <= 1.0);
                kept[e0] += 1;
            }
        }
        for (e, &c) in kept.iter().enumerate() {
            assert!(c <= cap, "expert {e} kept {c} tokens, capacity {cap}");
        }
        // a 0.5 capacity factor over a random batch must actually drop
        assert!(dropped > 0, "expected drops at capacity_factor=0.5");
        // conservation: kept + dropped = nb
        assert_eq!(kept.iter().sum::<usize>() + dropped, nb);
    }

    #[test]
    fn switch_gate_generous_capacity_drops_nothing() {
        let (nb, ne, k) = (40, 8, 2);
        let s = scores(nb, ne, 11);
        let gate = SwitchGate::new(8.0).unwrap();
        let a = gate.route(&s, k).unwrap();
        for i in 0..nb {
            assert!(a.w[i * k] > 0.0, "token {i} dropped despite slack capacity");
        }
    }

    #[test]
    fn switch_bwd_matches_finite_diff() {
        let (nb, ne, k) = (6, 5, 2);
        let s = scores(nb, ne, 9);
        let gate = SwitchGate::new(8.0).unwrap(); // no drops: smooth region
        let a = gate.route(&s, k).unwrap();
        let mut rng = Rng::new(10);
        let dw: Vec<f32> = (0..nb * k).map(|_| rng.normal() as f32).collect();
        let ds = gate.route_bwd(&a, &dw, ne).unwrap();
        let eps = 1e-3f32;
        for i in 0..nb {
            for e in 0..ne {
                let mut sp = s.clone();
                sp.data[i * ne + e] += eps;
                let mut sm = s.clone();
                sm.data[i * ne + e] -= eps;
                let ap = gate.route(&sp, k).unwrap();
                let am = gate.route(&sm, k).unwrap();
                if ap.idx != a.idx || am.idx != a.idx {
                    continue; // argmax set changed: not differentiable here
                }
                let f = |x: &GateAssign| -> f32 {
                    (0..nb * k).map(|a| x.w[a] * dw[a]).sum()
                };
                let fd = (f(&ap) - f(&am)) / (2.0 * eps);
                assert!(
                    (fd - ds.data[i * ne + e]).abs() < 2e-3,
                    "i={i} e={e}: fd={fd} ds={}",
                    ds.data[i * ne + e]
                );
            }
        }
    }

    #[test]
    fn noisy_gate_deterministic_under_seed() {
        let s = scores(24, 6, 5);
        let a = NoisyTopKGate::new(0.8, 42).unwrap();
        let b = NoisyTopKGate::new(0.8, 42).unwrap();
        // same seed ⇒ identical call sequences
        for _ in 0..3 {
            let ra = a.route(&s, 2).unwrap();
            let rb = b.route(&s, 2).unwrap();
            assert_eq!(ra.idx, rb.idx);
            assert_eq!(ra.w, rb.w);
        }
        // successive calls draw fresh noise from the stream
        let r1 = a.route(&s, 2).unwrap();
        let r2 = a.route(&s, 2).unwrap();
        assert!(r1.idx != r2.idx || r1.w != r2.w, "noise must vary per call");
        // a different seed routes differently
        let c = NoisyTopKGate::new(0.8, 43).unwrap();
        let rc = c.route(&s, 2).unwrap();
        let ra = NoisyTopKGate::new(0.8, 42).unwrap().route(&s, 2).unwrap();
        assert!(rc.idx != ra.idx || rc.w != ra.w);
    }

    #[test]
    fn noisy_gate_zero_std_is_plain_topk() {
        let s = scores(16, 5, 8);
        let g = NoisyTopKGate::new(0.0, 1).unwrap();
        let want = topk_softmax(&s, 2).unwrap();
        let got = g.route(&s, 2).unwrap();
        assert_eq!(got.idx, want.idx);
        assert_eq!(got.w, want.w);
    }

    #[test]
    fn balance_grad_zero_coef_and_balanced_routing_are_noops() {
        let (nb, ne) = (8usize, 4usize);
        // perfectly uniform probabilities + uniform counts
        let a = GateAssign {
            nb,
            k: 1,
            idx: (0..nb).map(|i| (i % ne) as u32).collect(),
            w: vec![1.0; nb],
            probs: Some(TensorF32::full(&[nb, ne], 1.0 / ne as f32)),
        };
        let counts = vec![2u32; ne];
        let mut ds = TensorF32::zeros(&[nb, ne]);
        TopKSoftmaxGate.balance_grad(&a, &counts, 0.0, &mut ds);
        assert!(ds.data.iter().all(|&v| v == 0.0), "coef 0 must be a no-op");
        TopKSoftmaxGate.balance_grad(&a, &counts, 1.0, &mut ds);
        assert!(
            ds.data.iter().all(|&v| v.abs() < 1e-7),
            "balanced routing sits at the loss minimum"
        );
    }

    #[test]
    fn balance_grad_drains_the_hot_expert() {
        let (nb, ne, k) = (16usize, 4usize, 2usize);
        let mut s = TensorF32::zeros(&[nb, ne]);
        for i in 0..nb {
            s.data[i * ne] = 4.0; // every token prefers expert 0
        }
        let gate = TopKSoftmaxGate;
        let a = gate.route(&s, k).unwrap();
        let counts = a.kept_counts(ne);
        assert_eq!(counts[0] as usize, nb);
        let mut ds = TensorF32::zeros(&[nb, ne]);
        gate.balance_grad(&a, &counts, 1.0, &mut ds);
        for i in 0..nb {
            let row = &ds.data[i * ne..(i + 1) * ne];
            // descent (θ −= lr·ds) must lower the hot expert's score
            assert!(row[0] > 0.0, "row {i}: hot expert grad {}", row[0]);
            let sum: f32 = row.iter().sum();
            assert!(sum.abs() < 1e-6, "row {i}: softmax grad rows sum to 0");
        }
    }

    #[test]
    fn balance_grad_moves_gate_weights_under_imbalanced_routing() {
        // End-to-end direction without artifacts: scores = x·wg, the
        // balance gradient alone (dw cotangent = 0) must produce a
        // nonzero dwg = xᵀ·dscores, i.e. real gate-weight movement.
        let (nb, dm, ne) = (12usize, 3usize, 4usize);
        let mut x = TensorF32::zeros(&[nb, dm]);
        Rng::new(4).fill_normal(&mut x.data, 1.0);
        for v in x.data.iter_mut() {
            *v = v.abs() + 0.1; // positive features: the biased column wins
        }
        let mut wg = TensorF32::zeros(&[dm, ne]);
        Rng::new(5).fill_normal(&mut wg.data, 0.02);
        // bias column 0 so routing collapses onto expert 0
        for d in 0..dm {
            wg.data[d * ne] += 2.0;
        }
        let scores = ops::matmul(&x, &wg).unwrap();
        let gate = TopKSoftmaxGate;
        let a = gate.route(&scores, 1).unwrap();
        let counts = a.kept_counts(ne);
        assert!(counts[0] as usize > nb / 2, "routing not imbalanced");
        let mut ds = TensorF32::zeros(&[nb, ne]);
        gate.balance_grad(&a, &counts, 0.5, &mut ds);
        // dwg[d][e] = Σ_i x[i][d] · ds[i][e]
        let mut dwg = TensorF32::zeros(&[dm, ne]);
        for i in 0..nb {
            for d in 0..dm {
                for e in 0..ne {
                    dwg.data[d * ne + e] += x.data[i * dm + d] * ds.data[i * ne + e];
                }
            }
        }
        assert!(dwg.l2_norm() > 1e-6, "balance loss must reach the gate GEMM");
        let before = wg.clone();
        ops::axpy(&mut wg, -0.1, &dwg).unwrap();
        assert!(
            ops::max_abs_diff(&wg, &before).unwrap() > 1e-7,
            "gate weights did not move"
        );
    }

    #[test]
    fn from_config_selects_and_validates() {
        let mut cfg = MoeConfig::default();
        assert_eq!(from_config(&cfg, 1).unwrap().name(), "topk");
        cfg.gate = "switch".into();
        assert_eq!(from_config(&cfg, 1).unwrap().name(), "switch");
        cfg.gate = "noisy_topk".into();
        assert_eq!(from_config(&cfg, 1).unwrap().name(), "noisy_topk");
        cfg.gate = "mystery".into();
        assert!(from_config(&cfg, 1).is_err());
        cfg.gate = "switch".into();
        cfg.capacity_factor = 0.0;
        assert!(from_config(&cfg, 1).is_err());
    }
}
