//! The crown-jewel integration test: the distributed (stage-mode,
//! Figure-2) MoE layer must compute *exactly the same function* as the
//! fused single-program artifact, forward and backward.
//!
//! Setup: W workers, each fed the SAME token batch and holding one
//! expert shard.  Then:
//!   * forward outputs match `moe_fwd_e{W·ne_local}` per worker;
//!   * backward `dx`, `dwg`, `dbg` match the fused `moe_grad_*`;
//!   * expert-shard grads equal W × the fused shard grads (each shard
//!     saw W identical copies of the batch).

use std::sync::Arc;

use fastmoe::comm::{run_workers, Comm};
use fastmoe::coordinator::DistMoeLayer;
use fastmoe::metrics::Counters;
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::tensor::{ops, HostTensor, TensorF32};

fn runtime() -> Option<Arc<Runtime>> {
    Runtime::open_default().ok().map(Arc::new)
}

struct Fused {
    y: TensorF32,
    loss: f32,
    dx: TensorF32,
    dwg: TensorF32,
    dbg: TensorF32,
    dw1: TensorF32,
    db1: TensorF32,
    dw2: TensorF32,
    db2: TensorF32,
}

/// Run the fused fwd + grad artifacts with assembled global weights.
fn run_fused(
    rt: &Runtime,
    ne: usize,
    x: &TensorF32,
    wg: &TensorF32,
    bg: &TensorF32,
    w1: &TensorF32,
    b1: &TensorF32,
    w2: &TensorF32,
    b2: &TensorF32,
) -> Fused {
    let inputs: Vec<HostTensor> = vec![
        x.clone().into(),
        wg.clone().into(),
        bg.clone().into(),
        w1.clone().into(),
        b1.clone().into(),
        w2.clone().into(),
        b2.clone().into(),
    ];
    let fwd = rt.executable(&format!("moe_fwd_e{ne}")).unwrap();
    let y = fwd.run(&inputs).unwrap().remove(0).into_f32().unwrap();
    let grad = rt.executable(&format!("moe_grad_e{ne}")).unwrap();
    let mut out = grad.run(&inputs).unwrap().into_iter();
    Fused {
        y,
        loss: out.next().unwrap().into_f32().unwrap().data[0],
        dx: out.next().unwrap().into_f32().unwrap(),
        dwg: out.next().unwrap().into_f32().unwrap(),
        dbg: out.next().unwrap().into_f32().unwrap(),
        dw1: out.next().unwrap().into_f32().unwrap(),
        db1: out.next().unwrap().into_f32().unwrap(),
        dw2: out.next().unwrap().into_f32().unwrap(),
        db2: out.next().unwrap().into_f32().unwrap(),
    }
}

fn assert_close(a: &TensorF32, b: &TensorF32, tol: f32, what: &str) {
    assert_eq!(a.shape, b.shape, "{what}: shapes");
    let scale = 1e-3 + b.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let diff = ops::max_abs_diff(a, b).unwrap();
    assert!(
        diff <= tol * scale,
        "{what}: max abs diff {diff} (scale {scale}, tol {tol})"
    );
}

#[test]
fn staged_layer_equals_fused_artifact() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let workers = 2usize;
    // topology check: need gate_fwd_w2 and a matching fused artifact
    let Some(gate) = rt.manifest.artifact(&format!("gate_fwd_w{workers}")) else {
        return;
    };
    let ne_global = gate.inputs[1].shape[1];
    if rt.manifest.artifact(&format!("moe_fwd_e{ne_global}")).is_none() {
        eprintln!("skipping: no fused artifact for {ne_global} experts");
        return;
    }

    let seed = 0xD15C0;
    let results = run_workers(workers, {
        let rt = rt.clone();
        move |mut h| {
            let layer = DistMoeLayer::init(rt.clone(), workers, h.rank(), seed)?;
            // identical batch on every worker (see module docs)
            let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
            Rng::new(99).fill_normal(&mut x.data, 1.0);
            let mut counters = Counters::new();
            let (y, state) = layer.forward(&mut h, x.clone(), &mut counters)?;

            // cotangent of loss = 0.5 * mean(y²):  dy = y / numel
            let mut dy = y.clone();
            let n = (layer.nb * layer.dm) as f32;
            for v in dy.data.iter_mut() {
                *v /= n;
            }
            let grads = layer.backward(&mut h, &state, &dy, &mut counters)?;
            Ok((h.rank(), layer, y, grads))
        }
    })
    .unwrap();

    // assemble global weights from the shards
    let l0 = &results[0].1;
    let (dm, dh, nel) = (l0.dm, l0.dh, l0.ne_local);
    let mut w1 = TensorF32::zeros(&[ne_global, dm, dh]);
    let mut b1 = TensorF32::zeros(&[ne_global, dh]);
    let mut w2 = TensorF32::zeros(&[ne_global, dh, dm]);
    let mut b2 = TensorF32::zeros(&[ne_global, dm]);
    for (rank, layer, _, _) in &results {
        let off = rank * nel;
        let shard = |name: &str| &layer.expert().param(name).unwrap().data;
        w1.data[off * dm * dh..(off + nel) * dm * dh].copy_from_slice(shard("w1"));
        b1.data[off * dh..(off + nel) * dh].copy_from_slice(shard("b1"));
        w2.data[off * dh * dm..(off + nel) * dh * dm].copy_from_slice(shard("w2"));
        b2.data[off * dm..(off + nel) * dm].copy_from_slice(shard("b2"));
    }
    let mut x = TensorF32::zeros(&[l0.nb, dm]);
    Rng::new(99).fill_normal(&mut x.data, 1.0);
    let fused = run_fused(&rt, ne_global, &x, &l0.wg, &l0.bg, &w1, &b1, &w2, &b2);
    assert!(fused.loss.is_finite());

    for (rank, layer, y, grads) in &results {
        // ---- forward ----
        assert_close(y, &fused.y, 2e-4, &format!("y (worker {rank})"));
        // ---- backward: per-token grads equal the fused ones ----
        assert_close(&grads.dx, &fused.dx, 5e-4, "dx");
        assert_close(&grads.dwg, &fused.dwg, 5e-4, "dwg");
        assert_close(&grads.dbg, &fused.dbg, 5e-4, "dbg");
        // ---- expert shard grads = W × fused shard (W identical batches) ----
        let off = rank * nel;
        let take = |t: &TensorF32, stride: usize| -> Vec<f32> {
            t.data[off * stride..(off + nel) * stride].to_vec()
        };
        let cmp_scaled = |got: &TensorF32, fused_all: &TensorF32, stride: usize, what: &str| {
            let want = take(fused_all, stride);
            assert_eq!(got.data.len(), want.len(), "{what} len");
            let scale = 1e-6 + want.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            for (i, (g, w)) in got.data.iter().zip(&want).enumerate() {
                let w2x = w * workers as f32;
                assert!(
                    (g - w2x).abs() <= 1e-3 * scale.max(w2x.abs()),
                    "{what}[{i}]: {g} vs {w2x}"
                );
            }
        };
        cmp_scaled(grads.expert_grad("w1").unwrap(), &fused.dw1, dm * dh, "dw1");
        cmp_scaled(grads.expert_grad("b1").unwrap(), &fused.db1, dh, "db1");
        cmp_scaled(grads.expert_grad("w2").unwrap(), &fused.dw2, dh * dm, "dw2");
        cmp_scaled(grads.expert_grad("b2").unwrap(), &fused.db2, dm, "db2");
    }
}

#[test]
fn distinct_batches_still_finite_and_conserving() {
    let Some(rt) = runtime() else { return };
    let workers = 4usize;
    if rt
        .manifest
        .artifact(&format!("gate_fwd_w{workers}"))
        .is_none()
    {
        return;
    }
    let results = run_workers(workers, {
        let rt = rt.clone();
        move |mut h| {
            let layer = DistMoeLayer::init(rt.clone(), workers, h.rank(), 5)?;
            let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
            Rng::new(1000 + h.rank() as u64).fill_normal(&mut x.data, 1.0);
            let mut counters = Counters::new();
            let (y, state) = layer.forward(&mut h, x, &mut counters)?;
            let rows: usize = state.eb.rows_per_expert.iter().sum();
            let routed: u32 = state.counts_global.iter().sum();
            Ok((y, rows, routed, layer.nb, layer.k))
        }
    })
    .unwrap();
    // token conservation across the exchange: total rows processed by
    // all workers == total assignments produced by all workers
    let total_rows: usize = results.iter().map(|(_, r, _, _, _)| r).sum();
    let total_assigned: u32 = results.iter().map(|(_, _, a, _, _)| a).sum();
    let (nb, k) = (results[0].3, results[0].4);
    assert_eq!(total_rows, workers * nb * k);
    assert_eq!(total_assigned as usize, workers * nb * k);
    for (y, _, _, _, _) in &results {
        assert!(y.data.iter().all(|v| v.is_finite()));
        assert!(y.l2_norm() > 0.0);
    }
}
