//! Regression test for the execute-path memory leak.
//!
//! The pinned xla_extension's literal-argument `execute` leaks its
//! implicit transfer buffers (~40 KiB per call), which OOM-killed a
//! 300-step training run at 35 GB RSS.  `Executable::run` now routes
//! through explicit device buffers (`execute_b`), which is leak-free.
//! This test pins that: 400 executions must not grow RSS by more than
//! a few MB.

use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::tensor::{HostTensor, TensorF32};

fn rss_bytes() -> usize {
    let s = std::fs::read_to_string("/proc/self/statm").unwrap();
    let pages: usize = s.split_whitespace().nth(1).unwrap().parse().unwrap();
    pages * 4096
}

#[test]
fn repeated_execution_does_not_leak() {
    let Ok(rt) = Runtime::open_default() else { return };
    let exe = rt.executable("quickstart_moe").unwrap();
    let mut rng = Rng::new(1);
    let inputs: Vec<HostTensor> = exe
        .meta
        .inputs
        .iter()
        .map(|s| {
            let mut t = TensorF32::zeros(&s.shape);
            rng.fill_normal(&mut t.data, 0.3);
            HostTensor::F32(t)
        })
        .collect();

    // warm allocators/caches
    for _ in 0..50 {
        let _ = exe.run(&inputs).unwrap();
    }
    let before = rss_bytes();
    for _ in 0..400 {
        let _ = exe.run(&inputs).unwrap();
    }
    let grown = rss_bytes().saturating_sub(before);
    // the old literal-execute path leaked ~40 KiB/call => ~16 MB here
    assert!(
        grown < 4 << 20,
        "execution leaked {} bytes over 400 calls",
        grown
    );
}
