//! Load-balance monitoring — the paper's §6 "future work", implemented.
//!
//! Tracks per-expert token counts across iterations, reports imbalance
//! statistics, and computes the GShard-style auxiliary balance loss
//! `n_e · Σ_e f_e · p_e` (fraction of tokens routed to expert e times
//! the mean gate probability of e), which the training loop can add to
//! the LM loss.

use crate::tensor::TensorF32;
use std::collections::VecDeque;

/// Running per-expert load statistics.
#[derive(Clone, Debug)]
pub struct LoadMonitor {
    pub n_expert: usize,
    /// Exponential moving average of the per-iteration load fraction.
    ema: Vec<f64>,
    /// Cumulative counts over all iterations.
    total: Vec<u64>,
    decay: f64,
    iterations: u64,
    /// Sliding-window length (0 = cumulative-only, no ring kept).
    window: usize,
    /// Ring of the most recent `window` recorded counts.
    recent: VecDeque<Vec<u32>>,
}

impl LoadMonitor {
    pub fn new(n_expert: usize) -> Self {
        Self {
            n_expert,
            ema: vec![1.0 / n_expert as f64; n_expert],
            total: vec![0; n_expert],
            decay: 0.99,
            iterations: 0,
            window: 0,
            recent: VecDeque::new(),
        }
    }

    /// [`LoadMonitor::new`] plus a sliding window: the last `window`
    /// records stay queryable for recency-weighted decisions (the
    /// placement [`Rebalancer`] keys off these, not lifetime totals).
    ///
    /// `window = 0` would mean "windowed but remember nothing", which
    /// no caller can want — it is a documented alias for `window = 1`
    /// (only the latest record), NOT for the unwindowed
    /// [`LoadMonitor::new`] (whose `window_totals` fall back to
    /// lifetime totals).
    ///
    /// [`Rebalancer`]: crate::placement::Rebalancer
    pub fn windowed(n_expert: usize, window: usize) -> Self {
        let mut m = Self::new(n_expert);
        m.window = window.max(1);
        m
    }

    /// Record one iteration's per-expert token counts.
    ///
    /// A zero-total iteration (every expert idle — a zombie rank's
    /// zeroed batch, a drained serve step) counts toward
    /// [`LoadMonitor::iterations`] but touches *nothing else*: not the
    /// EMA, not the totals, and not the sliding ring — it previously
    /// entered the ring while skipping the EMA/totals, silently
    /// evicting a real record and skewing `window_totals` against the
    /// cumulative view.
    pub fn record(&mut self, counts: &[u32]) {
        assert_eq!(counts.len(), self.n_expert);
        let total: u64 = counts.iter().map(|&c| c as u64).sum();
        self.iterations += 1;
        if total == 0 {
            return;
        }
        if self.window > 0 {
            if self.recent.len() == self.window {
                self.recent.pop_front();
            }
            self.recent.push_back(counts.to_vec());
        }
        for (e, &c) in counts.iter().enumerate() {
            self.total[e] += c as u64;
            let frac = c as f64 / total as f64;
            self.ema[e] = self.decay * self.ema[e] + (1.0 - self.decay) * frac;
        }
    }

    /// max/mean load ratio over the EMA (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let mean: f64 = self.ema.iter().sum::<f64>() / self.n_expert as f64;
        if mean <= 0.0 {
            return 1.0;
        }
        self.ema.iter().cloned().fold(0.0, f64::max) / mean
    }

    /// Coefficient of variation of cumulative loads.
    pub fn cv(&self) -> f64 {
        let n = self.n_expert as f64;
        let mean = self.total.iter().sum::<u64>() as f64 / n;
        if mean <= 0.0 {
            return 0.0;
        }
        let var = self
            .total
            .iter()
            .map(|&c| (c as f64 - mean).powi(2))
            .sum::<f64>()
            / n;
        var.sqrt() / mean
    }

    /// Experts that received < `frac` of the fair share, cumulatively.
    pub fn starved(&self, frac: f64) -> Vec<usize> {
        let fair = self.total.iter().sum::<u64>() as f64 / self.n_expert as f64;
        self.total
            .iter()
            .enumerate()
            .filter(|(_, &c)| (c as f64) < frac * fair)
            .map(|(e, _)| e)
            .collect()
    }

    pub fn totals(&self) -> &[u64] {
        &self.total
    }

    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    /// Records currently held in the sliding window.
    pub fn window_len(&self) -> usize {
        self.recent.len()
    }

    /// Per-expert counts summed over the sliding window (falls back to
    /// lifetime totals for a non-windowed monitor).
    pub fn window_totals(&self) -> Vec<u64> {
        if self.window == 0 {
            return self.total.clone();
        }
        let mut out = vec![0u64; self.n_expert];
        for rec in &self.recent {
            for (e, &c) in rec.iter().enumerate() {
                out[e] += c as u64;
            }
        }
        out
    }

    /// The expert with the most window load (ties: lowest id), or
    /// `None` when the window saw no tokens at all.
    pub fn hottest(&self) -> Option<usize> {
        let totals = self.window_totals();
        let (e, &c) = totals
            .iter()
            .enumerate()
            .max_by_key(|&(e, &c)| (c, std::cmp::Reverse(e)))?;
        if c == 0 {
            None
        } else {
            Some(e)
        }
    }
}

/// GShard auxiliary balance loss from one iteration's counts and the
/// full softmax gate probabilities `probs: [nb, n_e]`.
pub fn balance_loss(counts: &[u32], probs: &TensorF32) -> f64 {
    let (nb, ne) = match probs.dims2() {
        Ok(d) => d,
        Err(_) => return 0.0,
    };
    debug_assert_eq!(counts.len(), ne);
    let total: u64 = counts.iter().map(|&c| c as u64).sum();
    if total == 0 || nb == 0 {
        return 0.0;
    }
    let mut loss = 0.0;
    for e in 0..ne {
        let f_e = counts[e] as f64 / total as f64;
        let p_e: f64 = (0..nb)
            .map(|i| probs.data[i * ne + e] as f64)
            .sum::<f64>()
            / nb as f64;
        loss += f_e * p_e;
    }
    loss * ne as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_load_is_one() {
        let mut m = LoadMonitor::new(4);
        for _ in 0..100 {
            m.record(&[10, 10, 10, 10]);
        }
        assert!((m.imbalance() - 1.0).abs() < 1e-9);
        assert_eq!(m.cv(), 0.0);
        assert!(m.starved(0.5).is_empty());
    }

    #[test]
    fn skewed_load_detected() {
        let mut m = LoadMonitor::new(4);
        for _ in 0..200 {
            m.record(&[97, 1, 1, 1]);
        }
        assert!(m.imbalance() > 3.0, "imbalance={}", m.imbalance());
        assert_eq!(m.starved(0.5), vec![1, 2, 3]);
        assert!(m.cv() > 1.0);
    }

    #[test]
    fn zero_iteration_safe() {
        let mut m = LoadMonitor::new(2);
        m.record(&[0, 0]);
        assert!((m.imbalance() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn windowed_counts_roll_off() {
        let mut m = LoadMonitor::windowed(2, 3);
        m.record(&[100, 0]); // will age out
        m.record(&[1, 2]);
        m.record(&[3, 4]);
        m.record(&[5, 6]);
        assert_eq!(m.window_len(), 3);
        assert_eq!(m.window_totals(), vec![9, 12]);
        // lifetime totals still see everything
        assert_eq!(m.totals(), &[109, 12]);
        // an unwindowed monitor reports lifetime totals as its window
        let mut u = LoadMonitor::new(2);
        u.record(&[7, 1]);
        assert_eq!(u.window_len(), 0);
        assert_eq!(u.window_totals(), vec![7, 1]);
    }

    #[test]
    fn zero_total_iterations_stay_out_of_the_ring() {
        // Pre-fix, a zero-total record entered the sliding ring (while
        // correctly skipping EMA/totals), evicting a real record: after
        // [5,5], [7,7], [0,0] a window-2 monitor reported [7,7].
        let mut m = LoadMonitor::windowed(2, 2);
        m.record(&[5, 5]);
        m.record(&[7, 7]);
        m.record(&[0, 0]);
        assert_eq!(m.iterations(), 3, "idle iterations still count");
        assert_eq!(m.window_len(), 2);
        assert_eq!(
            m.window_totals(),
            vec![12, 12],
            "an idle iteration must not evict a real record"
        );
        assert_eq!(m.totals(), &[12, 12], "window and lifetime agree");
        assert_eq!(m.hottest(), Some(0));
        // windowed(n, 0) is the documented alias for window = 1 — the
        // latest record, not the unwindowed lifetime fallback
        let mut w = LoadMonitor::windowed(2, 0);
        w.record(&[3, 1]);
        w.record(&[1, 9]);
        assert_eq!(w.window_len(), 1);
        assert_eq!(w.window_totals(), vec![1, 9]);
    }

    #[test]
    fn hot_expert_detected_under_injected_skew() {
        let mut m = LoadMonitor::windowed(4, 8);
        // balanced warm-up that must NOT linger past the window
        for _ in 0..50 {
            m.record(&[10, 10, 10, 10]);
        }
        for _ in 0..8 {
            m.record(&[2, 2, 40, 2]);
        }
        assert_eq!(m.hottest(), Some(2));
        let w = m.window_totals();
        assert_eq!(w, vec![16, 16, 320, 16]);
        // empty window → no hot expert
        let mut z = LoadMonitor::windowed(4, 2);
        z.record(&[0, 0, 0, 0]);
        assert_eq!(z.hottest(), None);
        // ties resolve to the lowest id on every rank identically
        let mut t = LoadMonitor::windowed(3, 2);
        t.record(&[5, 5, 1]);
        assert_eq!(t.hottest(), Some(0));
    }

    #[test]
    fn capacity_dropped_tokens_are_not_load() {
        use crate::moe::GateAssign;
        // 4 assignments to expert 0 but two were capacity-dropped
        // (zero gate weight): kept_counts excludes them, so the
        // monitor never sees phantom load
        let assign = GateAssign {
            nb: 4,
            k: 1,
            idx: vec![0, 0, 1, 0],
            w: vec![0.9, 0.0, 1.0, 0.0],
            probs: None,
        };
        let kept = assign.kept_counts(2);
        assert_eq!(kept, vec![1, 1]);
        let mut m = LoadMonitor::windowed(2, 4);
        m.record(&kept);
        assert_eq!(m.window_totals(), vec![1, 1]);
        assert_eq!(m.hottest(), Some(0));
        assert!((m.imbalance() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn balance_loss_minimised_when_uniform() {
        // uniform probs + uniform counts => loss == 1.0 (the minimum)
        let ne = 4;
        let probs = TensorF32::full(&[8, ne], 1.0 / ne as f32);
        let uniform = balance_loss(&[2, 2, 2, 2], &probs);
        assert!((uniform - 1.0).abs() < 1e-6);
        // concentrated counts with matching concentrated probs => higher
        let mut conc = TensorF32::zeros(&[8, ne]);
        for i in 0..8 {
            conc.data[i * ne] = 1.0;
        }
        let skew = balance_loss(&[8, 0, 0, 0], &conc);
        assert!(skew > 3.9, "skew={skew}");
    }
}
