//! `manifest.json` model: the ABI contract between aot.py and Rust.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

/// One AOT artifact: file + positional ABI + free-form meta.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl ArtifactMeta {
    /// Meta field as usize (bucket, n_expert, …).
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.as_usize())
    }

    pub fn family(&self) -> &str {
        self.meta.get("family").and_then(|v| v.as_str()).unwrap_or("")
    }

    pub fn kind(&self) -> &str {
        self.meta.get("kind").and_then(|v| v.as_str()).unwrap_or("")
    }
}

/// One parameter of a model registry (ordered!).
#[derive(Clone, Debug, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String, // "normal:<std>" | "zeros" | "ones"
    pub tag: SyncTag,
}

/// FastMoE §3.2 gradient-synchronisation tags.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SyncTag {
    /// Replicated on every worker (the gate network).
    World,
    /// Replicated within a data-parallel group (attention, norms, …).
    DataParallel,
    /// Expert-parallel shard, never synchronised.
    None,
}

impl SyncTag {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "world" => Ok(SyncTag::World),
            "data_parallel" => Ok(SyncTag::DataParallel),
            "none" => Ok(SyncTag::None),
            other => Err(Error::Manifest(format!("unknown sync tag `{other}`"))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SyncTag::World => "world",
            SyncTag::DataParallel => "data_parallel",
            SyncTag::None => "none",
        }
    }
}

/// A model registry entry: ordered params + step artifact names + config.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub params: Vec<ParamEntry>,
    pub train_step: String,
    pub eval_step: String,
    pub grad_step: String,
    pub config: Json,
}

impl ModelEntry {
    pub fn n_params(&self) -> usize {
        self.params
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum()
    }

    pub fn config_usize(&self, key: &str) -> Option<usize> {
        self.config.get(key).and_then(|v| v.as_usize())
    }
}

/// The whole parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub preset_params: Json,
    pub artifacts: Vec<ArtifactMeta>,
    pub models: BTreeMap<String, ModelEntry>,
    by_name: BTreeMap<String, usize>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let preset = j.str_or("preset", "unknown");
        let preset_params = j.get("preset_params").cloned().unwrap_or(Json::Null);

        let mut artifacts = Vec::new();
        for a in j
            .req("artifacts")?
            .as_array()
            .ok_or_else(|| Error::Manifest("artifacts not an array".into()))?
        {
            artifacts.push(parse_artifact(a)?);
        }

        let mut models = BTreeMap::new();
        if let Some(Json::Object(m)) = j.get("models") {
            for (name, entry) in m {
                models.insert(name.clone(), parse_model(name, entry)?);
            }
        }

        let by_name = artifacts
            .iter()
            .enumerate()
            .map(|(i, a)| (a.name.clone(), i))
            .collect();

        Ok(Manifest { preset, preset_params, artifacts, models, by_name })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_name.get(name).map(|&i| &self.artifacts[i])
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown model `{name}`")))
    }

    /// Artifacts of one family ("fig5", "stage", …), manifest order.
    pub fn family(&self, family: &str) -> Vec<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| a.family() == family)
            .collect()
    }

    /// Available expert-fwd buckets, ascending.
    pub fn buckets(&self) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind() == "expert_fwd")
            .filter_map(|a| a.meta_usize("bucket"))
            .collect();
        b.sort_unstable();
        b
    }
}

fn parse_spec(j: &Json, idx: usize) -> Result<TensorSpec> {
    let shape = j
        .req("shape")?
        .as_array()
        .ok_or_else(|| Error::Manifest("shape not array".into()))?
        .iter()
        .map(|v| {
            v.as_usize()
                .ok_or_else(|| Error::Manifest("bad shape element".into()))
        })
        .collect::<Result<Vec<usize>>>()?;
    let dtype = j.str_or("dtype", "f32");
    let name = j.str_or("name", &format!("arg{idx}"));
    Ok(TensorSpec { name, shape, dtype })
}

fn parse_artifact(j: &Json) -> Result<ArtifactMeta> {
    let name = j
        .req("name")?
        .as_str()
        .ok_or_else(|| Error::Manifest("artifact name not a string".into()))?
        .to_string();
    let file = j.str_or("file", &format!("{name}.hlo.txt"));
    let inputs = j
        .req("inputs")?
        .as_array()
        .ok_or_else(|| Error::Manifest("inputs not array".into()))?
        .iter()
        .enumerate()
        .map(|(i, s)| parse_spec(s, i))
        .collect::<Result<Vec<_>>>()?;
    let outputs = j
        .req("outputs")?
        .as_array()
        .ok_or_else(|| Error::Manifest("outputs not array".into()))?
        .iter()
        .enumerate()
        .map(|(i, s)| parse_spec(s, i))
        .collect::<Result<Vec<_>>>()?;
    let meta = j.get("meta").cloned().unwrap_or(Json::Null);
    Ok(ArtifactMeta { name, file, inputs, outputs, meta })
}

fn parse_model(name: &str, j: &Json) -> Result<ModelEntry> {
    let mut params = Vec::new();
    for p in j
        .req("params")?
        .as_array()
        .ok_or_else(|| Error::Manifest("params not array".into()))?
    {
        let pname = p
            .req("name")?
            .as_str()
            .ok_or_else(|| Error::Manifest("param name".into()))?
            .to_string();
        let shape = p
            .req("shape")?
            .as_array()
            .ok_or_else(|| Error::Manifest("param shape".into()))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| Error::Manifest("dim".into())))
            .collect::<Result<Vec<usize>>>()?;
        let init = p.str_or("init", "zeros");
        let tag = SyncTag::parse(&p.str_or("tag", "data_parallel"))?;
        params.push(ParamEntry { name: pname, shape, init, tag });
    }
    Ok(ModelEntry {
        name: name.to_string(),
        params,
        train_step: j.str_or("train_step", ""),
        eval_step: j.str_or("eval_step", ""),
        grad_step: j.str_or("grad_step", ""),
        config: j.get("config").cloned().unwrap_or(Json::Null),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "preset": "tiny",
      "preset_params": {"nb": 64},
      "artifacts": [
        {"name": "a", "file": "a.hlo.txt",
         "inputs": [{"name": "x", "shape": [2, 3], "dtype": "f32"}],
         "outputs": [{"index": 0, "shape": [2], "dtype": "i32"}],
         "meta": {"family": "stage", "kind": "expert_fwd", "bucket": 64}},
        {"name": "b", "file": "b.hlo.txt", "inputs": [], "outputs": [],
         "meta": {"family": "stage", "kind": "expert_fwd", "bucket": 16}}
      ],
      "models": {
        "m": {"config": {"seq": 4},
              "params": [{"name": "w", "shape": [2, 2],
                          "init": "normal:0.02", "tag": "none"}],
              "train_step": "ts", "eval_step": "es", "grad_step": "gs"}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.preset, "tiny");
        let a = m.artifact("a").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.outputs[0].dtype, "i32");
        assert_eq!(a.meta_usize("bucket"), Some(64));
        assert_eq!(m.buckets(), vec![16, 64]);
        let model = m.model("m").unwrap();
        assert_eq!(model.params[0].tag, SyncTag::None);
        assert_eq!(model.n_params(), 4);
        assert_eq!(model.config_usize("seq"), Some(4));
        assert!(m.model("missing").is_err());
    }

    #[test]
    fn rejects_bad_tag() {
        let bad = SAMPLE.replace("\"none\"", "\"sometimes\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn family_filter() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.family("stage").len(), 2);
        assert_eq!(m.family("fig5").len(), 0);
    }
}
