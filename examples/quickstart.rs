//! Quickstart: load an AOT-compiled MoE layer and run a forward pass.
//!
//! ```bash
//! make artifacts            # once: python lowers the HLO programs
//! cargo run --release --example quickstart
//! ```
//!
//! This is the whole three-layer story in ~50 lines: the Pallas kernels
//! and the JAX layer were lowered at build time; at run time Rust loads
//! the HLO text, compiles it on the PJRT CPU client, and executes it —
//! no python anywhere.

use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::tensor::{HostTensor, TensorF32};

fn main() -> fastmoe::Result<()> {
    // 1. Open the artifact directory (reads manifest.json).
    let rt = Runtime::open_default()?;
    println!("PJRT platform: {}", rt.platform());

    // 2. Compile the fused MoE layer (gate → scatter → experts → combine).
    let exe = rt.executable("quickstart_moe")?;
    let meta = &exe.meta;
    println!(
        "artifact `{}`: {} experts, top-{}, batch {} × d_model {}",
        meta.name,
        meta.meta_usize("n_expert").unwrap(),
        meta.meta_usize("top_k").unwrap(),
        meta.meta_usize("nb").unwrap(),
        meta.meta_usize("d_model").unwrap(),
    );

    // 3. Build random inputs straight from the manifest ABI.
    let mut rng = Rng::new(42);
    let inputs: Vec<HostTensor> = meta
        .inputs
        .iter()
        .map(|spec| {
            let mut t = TensorF32::zeros(&spec.shape);
            rng.fill_normal(&mut t.data, 0.5);
            HostTensor::F32(t)
        })
        .collect();

    // 4. Execute and inspect.
    let outputs = exe.run(&inputs)?;
    let y = outputs[0].as_f32()?;
    println!(
        "output: shape {:?}, ‖y‖₂ = {:.4}, first row: {:?}",
        y.shape,
        y.l2_norm(),
        &y.row(0)[..4.min(y.shape[1])]
    );
    println!("quickstart OK");
    Ok(())
}
