//! Figure 6: cross-worker scalability of the distributed MoE layer.
//!
//! Throughput (matmul FLOPs of the layer, fwd+bwd) against the number
//! of expert-parallel workers.  The Figure-2 exchange runs on the real
//! comm substrate; *device* time is simulated: this testbed has one
//! CPU core, so W workers are time-sliced and the measured group wall
//! time equals the total serial compute.  Each simulated device gets
//! `wall / W` of compute per worker, overlapped across workers, plus
//! α-β wire time for its egress — exactly the paper's topology of one
//! device per node over Infiniband EDR (substitution table, DESIGN.md
//! §1).  The net model is *scaled* so the comm:compute ratio matches
//! the paper's V100 testbed (a V100 does ~14 TFLOPs against a 12.5
//! GB/s link; this CPU does ~0.05 TFLOPs, so the simulated link is
//! slowed by the same factor — otherwise communication would be
//! invisibly cheap and the figure's shape unreproducible).
//!
//! ```bash
//! cargo bench --bench fig6_scale                    # scaled IB-EDR (default)
//! cargo bench --bench fig6_scale -- --net ib-edr    # unscaled wire time
//! cargo bench --bench fig6_scale -- --net none      # ablation: free network
//! ```
//!
//! Expected shape (paper Fig. 6): going 1→2 workers roughly *halves*
//! per-worker efficiency (communication appears); 2→8 grows aggregate
//! throughput sub-linearly (paper: 10 → 25 TFLOPs, ≈2.5×).

use std::sync::Arc;

use fastmoe::bench::Table;
use fastmoe::cli::Args;
use fastmoe::comm::{run_workers, Comm};
use fastmoe::coordinator::DistMoeLayer;
use fastmoe::metrics::{Counters, CsvWriter, Stopwatch};
use fastmoe::rng::Rng;
use fastmoe::runtime::Runtime;
use fastmoe::sim::{NetModel, NetPreset};
use fastmoe::tensor::TensorF32;
use fastmoe::util::gflops;

fn main() -> fastmoe::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(argv, &[])?;
    let iters = args.usize_or("iters", 4)?;
    let net_name = args.str_or("net", "ib-edr-scaled");
    // V100 fp32 ≈ 14 TFLOP/s against 12.5 GB/s EDR (the paper's nodes)
    const PAPER_DEVICE_GFLOPS: f64 = 14_000.0;
    let rt = Arc::new(Runtime::open_default()?);

    // worker counts available in the preset (gate_fwd_w{N} artifacts)
    let mut worker_counts: Vec<usize> = rt
        .manifest
        .artifacts
        .iter()
        .filter(|a| a.kind() == "gate_fwd")
        .filter_map(|a| a.meta_usize("workers"))
        .collect();
    worker_counts.sort_unstable();
    println!(
        "Figure 6 — distributed MoE layer scalability (iters={iters}, net={net_name})\n"
    );

    let mut table = Table::new(&[
        "workers", "experts", "compute_s/dev", "wire_ms/iter", "agg_GFLOP/s",
        "efficiency", "a2a_MB/iter",
    ]);
    let mut csv = CsvWriter::create(
        "runs/fig6_scale.csv",
        &["workers", "agg_gflops", "compute_s_per_dev", "wire_ms_per_iter", "a2a_bytes_per_iter"],
    )?;
    let mut base: Option<f64> = None;
    let mut device_gflops: Option<f64> = None;

    for &w in &worker_counts {
        let rt2 = rt.clone();
        let results = run_workers(w, move |mut h| {
            let layer = DistMoeLayer::init(rt2.clone(), w, h.rank(), 11)?;
            layer.warm()?;
            let mut counters = Counters::new();
            let mut rng = Rng::new(100 + h.rank() as u64);
            let mut flops = 0.0f64;
            h.barrier();
            let watch = Stopwatch::start();
            for _ in 0..iters {
                let mut x = TensorF32::zeros(&[layer.nb, layer.dm]);
                rng.fill_normal(&mut x.data, 1.0);
                let (_, state) = layer.forward(&mut h, x, &mut counters)?;
                let dy = TensorF32::full(&[layer.nb, layer.dm], 1e-3);
                let _ = layer.backward(&mut h, &state, &dy, &mut counters)?;
                flops += 3.0 * layer.flops(&state);
            }
            h.barrier();
            Ok((watch.secs(), flops, counters.get("moe_a2a_bytes")))
        })?;

        // one core time-slices the workers: the group wall time is the
        // total serial compute; each simulated device does wall/W of it
        let wall = results.iter().map(|r| r.0).fold(0.0f64, f64::max);
        let total_flops: f64 = results.iter().map(|r| r.1).sum();
        let bytes_per_iter =
            results.iter().map(|r| r.2).max().unwrap_or(0) as usize / iters.max(1);
        let compute_per_dev = wall / w as f64;

        // calibrate the scaled net from the single-worker measurement
        if device_gflops.is_none() {
            device_gflops = Some(gflops(total_flops / w as f64, compute_per_dev));
        }
        let net = match net_name.as_str() {
            "ib-edr-scaled" => {
                let ratio = device_gflops.unwrap() / PAPER_DEVICE_GFLOPS;
                let base_net = NetModel::preset(NetPreset::IbEdr);
                NetModel {
                    alpha: base_net.alpha / ratio.max(1e-9),
                    beta: base_net.beta * ratio,
                    enabled: true,
                }
            }
            other => NetModel::preset(NetPreset::parse(other).unwrap_or(NetPreset::IbEdr)),
        };

        let wire_per_iter = net.all_to_all(w, bytes_per_iter);
        let sim_iter = compute_per_dev / iters as f64 + wire_per_iter;
        let agg = gflops(total_flops, sim_iter * iters as f64);
        let ne_global = rt
            .manifest
            .artifact(&format!("gate_fwd_w{w}"))
            .and_then(|a| a.meta_usize("n_expert_global"))
            .unwrap_or(0);
        if base.is_none() {
            base = Some(agg);
        }
        let eff = agg / (w as f64 * base.unwrap());
        table.row(vec![
            w.to_string(),
            ne_global.to_string(),
            format!("{compute_per_dev:.2}"),
            format!("{:.1}", wire_per_iter * 1e3),
            format!("{agg:.2}"),
            format!("{:.0}%", eff * 100.0),
            format!("{:.2}", bytes_per_iter as f64 / 1e6),
        ]);
        csv.rowf(&[
            w as f64,
            agg,
            compute_per_dev,
            wire_per_iter * 1e3,
            bytes_per_iter as f64,
        ])?;
        println!(
            "  {w} workers: {agg:.2} GFLOP/s aggregate ({:.1} ms wire / {:.0} ms compute per iter)",
            wire_per_iter * 1e3,
            compute_per_dev / iters as f64 * 1e3
        );
    }

    println!("\n{}", table.render());
    println!("runs/fig6_scale.csv written");
    Ok(())
}
