//! Command-line argument parsing substrate (no `clap` offline).
//!
//! Supports `binary <subcommand> --key value --flag positional…` with
//! typed accessors, defaults, and generated usage text.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    ///
    /// `bool_flags` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        bool_flags: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Cli("empty option name".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else {
                    let v = it.next().ok_or_else(|| {
                        Error::Cli(format!("option --{name} needs a value"))
                    })?;
                    out.options.insert(name.to_string(), v);
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env(bool_flags: &[&str]) -> Result<Args> {
        Args::parse(std::env::args().skip(1), bool_flags)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Cli(format!("--{name} expects an integer, got `{v}`"))
            }),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Cli(format!("--{name} expects a number, got `{v}`"))
            }),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Cli(format!("--{name} expects an integer, got `{v}`"))
            }),
        }
    }

    /// Enumerated option: the value (or `default`) must be one of
    /// `choices`, e.g. `--gate topk|switch|noisy_topk`.
    pub fn choice_or(
        &self,
        name: &str,
        choices: &[&str],
        default: &str,
    ) -> Result<String> {
        let v = self.str_or(name, default);
        if choices.contains(&v.as_str()) {
            Ok(v)
        } else {
            Err(Error::Cli(format!(
                "--{name} expects one of {choices:?}, got `{v}`"
            )))
        }
    }

    /// Comma-separated usize list, e.g. `--workers 1,2,4,8`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim().parse::<usize>().map_err(|_| {
                        Error::Cli(format!("--{name}: bad element `{p}`"))
                    })
                })
                .collect(),
        }
    }
}

/// Usage text builder for subcommand help.
pub struct Usage {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<(&'static str, &'static str)>,
}

impl Usage {
    pub fn render(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n",
            self.name, self.about, self.name);
        for (c, about) in &self.commands {
            s.push_str(&format!("  {c:<18} {about}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn subcommand_options_flags() {
        let a = Args::parse(argv("train --steps 100 --verbose x.toml"), &["verbose"]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["x.toml"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(argv("bench --iters=5 --lr=0.1"), &[]).unwrap();
        assert_eq!(a.usize_or("iters", 0).unwrap(), 5);
        assert!((a.f64_or("lr", 0.0).unwrap() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(argv("x --steps"), &[]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(argv("x --steps nan?"), &[]).unwrap();
        assert!(a.usize_or("steps", 1).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(argv("x --ws 1,2,4"), &[]).unwrap();
        assert_eq!(a.usize_list_or("ws", &[]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.usize_list_or("other", &[8]).unwrap(), vec![8]);
    }

    #[test]
    fn choice_validation() {
        let a = Args::parse(argv("x --gate switch"), &[]).unwrap();
        let kinds = ["topk", "switch", "noisy_topk"];
        assert_eq!(a.choice_or("gate", &kinds, "topk").unwrap(), "switch");
        // default passes through
        assert_eq!(a.choice_or("other", &kinds, "topk").unwrap(), "topk");
        // unknown value is an error
        let b = Args::parse(argv("x --gate random"), &[]).unwrap();
        assert!(b.choice_or("gate", &kinds, "topk").is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(argv("run"), &[]).unwrap();
        assert_eq!(a.str_or("model", "gpt_moe"), "gpt_moe");
        assert_eq!(a.usize_or("steps", 7).unwrap(), 7);
    }
}
