"""§6 future-work feature: the GShard balance loss implementation."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import layers


def _mk(rng, nb=24, dm=8, dh=16, ne=4):
    return dict(
        x=jnp.asarray(rng.standard_normal((nb, dm)), jnp.float32),
        wg=jnp.asarray(rng.standard_normal((dm, ne)), jnp.float32),
        bg=jnp.zeros(ne, jnp.float32),
        w1=jnp.asarray(rng.standard_normal((ne, dm, dh)) * 0.3, jnp.float32),
        b1=jnp.zeros((ne, dh), jnp.float32),
        w2=jnp.asarray(rng.standard_normal((ne, dh, dm)) * 0.3, jnp.float32),
        b2=jnp.zeros((ne, dm), jnp.float32),
    )


def test_aux_output_matches_plain_layer(rng):
    p = _mk(rng)
    y0 = layers.moe_ffn(**p, k=2, capacity=48)
    y1, aux = layers.moe_ffn_with_aux(**p, k=2, capacity=48)
    np.testing.assert_allclose(y0, y1, rtol=1e-5, atol=1e-6)
    assert float(aux) >= 1.0 - 1e-3  # n_e·Σf·p is minimised at 1


def test_aux_is_one_when_perfectly_balanced():
    # gate bias forces a uniform softmax; idx distribution round-robins
    nb, dm, ne = 16, 4, 4
    p = dict(
        x=jnp.zeros((nb, dm), jnp.float32),
        wg=jnp.zeros((dm, ne), jnp.float32),
        bg=jnp.zeros(ne, jnp.float32),
        w1=jnp.zeros((ne, dm, 8), jnp.float32),
        b1=jnp.zeros((ne, 8), jnp.float32),
        w2=jnp.zeros((ne, 8, dm), jnp.float32),
        b2=jnp.zeros((ne, dm), jnp.float32),
    )
    _, aux = layers.moe_ffn_with_aux(**p, k=2, capacity=nb * 2)
    # probs uniform (=1/4 each); f uniform over chosen experts
    assert abs(float(aux) - 1.0) < 1e-5


def test_aux_gradient_pushes_toward_balance(rng):
    """The gate gradient of the aux loss must reduce the probability of
    the over-loaded expert."""
    p = _mk(rng, nb=32)
    # bias the gate hard toward expert 0
    p["bg"] = jnp.asarray([5.0, 0.0, 0.0, 0.0], jnp.float32)

    def aux_only(bg):
        q = dict(p, bg=bg)
        _, aux = layers.moe_ffn_with_aux(**q, k=2, capacity=64)
        return aux

    g = jax.grad(aux_only)(p["bg"])
    # gradient on the hot expert's bias must be the most positive one
    # (gradient descent will lower it)
    assert int(jnp.argmax(g)) == 0, np.asarray(g)
