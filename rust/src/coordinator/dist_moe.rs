//! The distributed (expert-parallel) MoE layer — the heart of FastMoE.
//!
//! Each worker owns `ne_local` experts and runs, per iteration, the
//! stage chain of DESIGN.md §4 with the Figure-2 exchange in the
//! middle.  All heavy math is AOT-compiled HLO; this file is exactly
//! the coordination the paper contributes: planning, packing,
//! exchanging, bucketing, and the mirrored backward chain.
//!
//! Following §3.1's hierarchical interface, the layer itself is thin
//! orchestration over two swappable policies:
//!
//! * the [`Gate`] (which experts, at what weight) — see
//!   [`crate::moe::gate`];
//! * the [`ExpertShard`] (what an expert computes) — see
//!   [`crate::moe::expert`].
//!
//! Layers are assembled by [`MoeLayerBuilder`], normally from the
//! `[moe]` and `[comm]` config sections:
//!
//! ```ignore
//! let layer = MoeLayerBuilder::from_config(&cfg.moe()?)
//!     .comm_config(&cfg.comm()?)
//!     .seed(seed)
//!     .build(rt, workers, rank)?;
//! ```
//!
//! With `[comm] overlap = true` the Figure-2 exchanges run *pipelined*
//! (the §4 performance story): the dispatch decomposes into ring-offset
//! peer chunks over the nonblocking `isend`/`irecv` transport, chunk
//! `c+1`'s tokens flying while chunk `c` runs through the expert shard
//! and the return exchange streaming per chunk; the backward mirrors
//! this and additionally hides the gate GEMM backward behind the
//! cotangent flight.  `chunks = 1` (or `overlap = false`, the default)
//! is the blocking path with bit-identical outputs; `chunks = 0` picks
//! the count adaptively from the previous step's measured wire:compute
//! ratio (exchanged on the count round, so ranks stay in lockstep;
//! `[comm] chunk_policy` selects the mean or the straggler-aware max
//! reduction of those ratios).  Under a hierarchical `[comm] topology`
//! the chunk schedule is ordered most-local-first
//! ([`crate::moe::chunk_peer_groups_topo`]) and the blocking
//! collectives route through the node leaders when the layer is driven
//! over a [`crate::comm::TopoComm`] — both pure schedule changes, so
//! outputs stay bit-identical to flat modulo the documented all-reduce
//! ordering.
//!
//! The hot path is *allocation-free and copy-minimal in steady state*:
//! arriving rows land once in the pooled full-batch buffer, per-chunk
//! compute gathers slice views of it into one recycled staging (never
//! padded beyond the blocking bucket), the phase-1 count round rides
//! chunk 0's flight, and every send/recv/cotangent container cycles
//! through the layer's [`BufferPool`] ([`DistMoeLayer::recycle`]).
//! Copy and pool traffic surface as `moe_copy_bytes` / `pool_*`
//! counters; `rust/tests/zero_copy_regression.rs` pins zero
//! steady-state misses and the exact copy budget.
//!
//! [`DistMoeLayer::init`] remains as the seed-compatible shorthand for
//! the default top-k softmax gate + FFN shard (bit-identical routing
//! and weights to the pre-trait layer).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::comm::{Comm, CommRequest, PendingAllReduce, ProcessGroup, Topology};
use crate::config::{CommConfig, MoeConfig};
use crate::error::{Error, Result};
use crate::metrics::Counters;
use crate::model::{pack_expert_slot, unpack_expert_slot, Adam};
use crate::moe::{
    agree_chunks, balance_loss, chunk_peer_groups_topo, gate, post_chunk, wait_chunk,
    ChunkPolicy, DispatchPlan, ExpertBatch, ExpertShard, FfnExpertShard, Gate,
    GateAssign, PendingChunk,
};
use crate::placement::{shadow_salt, PlacementPlan, PlanDelta};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::tensor::{ops, BufferPool, PoolStats, TensorF32};

// Buffer-pool roles of the layer's step-persistent arena (see
// `tensor::pool`): keying by job keeps wildly different size classes
// from evicting each other.
/// Per-peer send/recv staging — row payloads *and* the tiny count
/// messages share this role on purpose: the comm backend's
/// [`Comm::reclaim_spent`] cannot tell origins apart, and the pool's
/// best-fit take + size-aware eviction make the mix safe (tiny
/// buffers neither satisfy big requests nor displace big residents).
const ROLE_WIRE: &str = "wire";
/// The padded full-batch expert container (forward residual).
const ROLE_BATCH: &str = "expert_batch";
/// Per-chunk compute staging (slice-view gather target).
const ROLE_STAGE: &str = "chunk_stage";
/// Backward cotangent container shaped like the batch.
const ROLE_COT: &str = "cotangent";
/// Packed `[nb·k, dm]` row tensors (combine input / packed cotangents).
const ROLE_PACKED: &str = "packed_rows";
/// The shadow-replica compute batch (placement-aware forward only):
/// its bucket tracks replica load, a different size class from the
/// main batch, so it gets its own role.
const ROLE_SHADOW: &str = "shadow_batch";

/// Tag code for placement slot transfers (`(seq << 8) | PLACE_TAG`);
/// the data/count/group/broadcast codes are 1/2/7/9.
const PLACE_TAG: u64 = 11;

/// Optimiser slot index where expert params start: the trainer's Adam
/// covers `[wg, bg, <expert params>...]` (see `MoeLayerTrainer::new`).
const GATE_OPT_SLOTS: usize = 2;

/// Adaptive-chunking state (`[comm] chunks = 0`): every rank's pick
/// must stay in lockstep (the chunk schedule and tag reservations are
/// part of the wire protocol), so the *measured* ratio is exchanged on
/// the folded count round and the *agreed* count only ever derives
/// from that shared data.
#[derive(Clone, Copy, Debug)]
struct AdaptState {
    /// Chunk count every rank agreed to use for the next pipelined step.
    chunks: usize,
    /// This rank's wire:compute ratio measured on its previous
    /// pipelined forward, f32-rounded (what peers will receive);
    /// negative = no measurement yet.
    my_ratio: f32,
}

/// Manifest-derived geometry shared by every layer built on a runtime.
#[derive(Clone, Debug)]
struct LayerGeom {
    nb: usize,
    dm: usize,
    dh: usize,
    ne_local: usize,
    k: usize,
    buckets: Vec<usize>,
}

/// Probe the artifact manifest for the layer geometry of a topology.
fn probe_geometry(rt: &Runtime, workers: usize) -> Result<LayerGeom> {
    let m = &rt.manifest;
    let gate = m
        .artifact(&format!("gate_fwd_w{workers}"))
        .ok_or_else(|| {
            Error::ArtifactNotFound(format!(
                "gate_fwd_w{workers} (worker count not in preset)"
            ))
        })?;
    let nb = gate.inputs[0].shape[0];
    let dm = gate.inputs[0].shape[1];
    let ne_global = gate.inputs[1].shape[1];
    let ne_local = ne_global / workers;
    let combine = m
        .artifact("combine_fwd")
        .ok_or_else(|| Error::ArtifactNotFound("combine_fwd".into()))?;
    let k = combine.inputs[1].shape[1];
    let buckets = m.buckets();
    if buckets.is_empty() {
        return Err(Error::Manifest("no expert buckets in manifest".into()));
    }
    // dh from any expert artifact
    let eart = m
        .artifact(&format!("expert_fwd_b{}", buckets[0]))
        .ok_or_else(|| Error::ArtifactNotFound("expert_fwd".into()))?;
    let dh = eart.inputs[1].shape[2];
    if eart.inputs[0].shape[0] != ne_local {
        return Err(Error::Manifest(format!(
            "expert artifact has {} local experts, topology wants {}",
            eart.inputs[0].shape[0], ne_local
        )));
    }
    Ok(LayerGeom { nb, dm, dh, ne_local, k, buckets })
}

/// Assembles a [`DistMoeLayer`] from a gate policy + expert shard.
///
/// The builder owns everything that *selects* modules (the `[moe]`
/// config section, the init seed); geometry comes from the artifact
/// manifest at [`MoeLayerBuilder::build`] time.
#[derive(Clone, Debug)]
pub struct MoeLayerBuilder {
    cfg: MoeConfig,
    comm: CommConfig,
    seed: u64,
}

impl Default for MoeLayerBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl MoeLayerBuilder {
    /// Default modules: top-k softmax gate + FFN expert shard,
    /// blocking (non-overlapped) exchanges.
    pub fn new() -> MoeLayerBuilder {
        MoeLayerBuilder {
            cfg: MoeConfig::default(),
            comm: CommConfig::default(),
            seed: 0,
        }
    }

    /// Select modules from a `[moe]` config section.
    pub fn from_config(cfg: &MoeConfig) -> MoeLayerBuilder {
        MoeLayerBuilder {
            cfg: cfg.clone(),
            comm: CommConfig::default(),
            seed: 0,
        }
    }

    /// Select the exchange schedule from a `[comm]` config section
    /// (overlap on/off, chunk count).
    pub fn comm_config(mut self, comm: &CommConfig) -> MoeLayerBuilder {
        self.comm = comm.clone();
        self
    }

    /// Override exchange/compute overlap directly.
    pub fn overlap(mut self, on: bool) -> MoeLayerBuilder {
        self.comm.overlap = on;
        self
    }

    /// Override the exchange chunk count directly (`0` = adaptive).
    pub fn chunks(mut self, chunks: usize) -> MoeLayerBuilder {
        self.comm.chunks = chunks;
        self
    }

    /// Override the step-persistent buffer pool on/off directly.
    pub fn pool(mut self, on: bool) -> MoeLayerBuilder {
        self.comm.pool = on;
        self
    }

    /// Override overlapped gate-grad sync directly (`[comm]
    /// grad_overlap`): the backward flies the replicated gate-grad
    /// bucket during the expert backward and returns it pre-averaged.
    pub fn grad_overlap(mut self, on: bool) -> MoeLayerBuilder {
        self.comm.grad_overlap = on;
        self
    }

    /// Override ZeRO optimizer-state sharding directly (`[comm]
    /// grad_shard = "zero"`): the gate's Adam state splits across
    /// ranks and steps through [`DistMoeLayer::apply_grads_zero`].
    pub fn grad_shard(mut self, on: bool) -> MoeLayerBuilder {
        self.comm.grad_shard = if on { "zero" } else { "none" }.into();
        self
    }

    /// Seed for parameter init (and the noisy gate's noise stream).
    pub fn seed(mut self, seed: u64) -> MoeLayerBuilder {
        self.seed = seed;
        self
    }

    /// Override the gate kind ("topk" | "switch" | "noisy_topk").
    pub fn gate(mut self, name: &str) -> MoeLayerBuilder {
        self.cfg.gate = name.to_string();
        self
    }

    /// Override the switch-gate capacity factor.
    pub fn capacity_factor(mut self, cf: f64) -> MoeLayerBuilder {
        self.cfg.capacity_factor = cf;
        self
    }

    /// Override the noisy-gate noise std.
    pub fn noise_std(mut self, std: f64) -> MoeLayerBuilder {
        self.cfg.noise_std = std;
        self
    }

    /// Override the balance-loss gradient weight.
    pub fn balance_coef(mut self, coef: f64) -> MoeLayerBuilder {
        self.cfg.balance_coef = coef;
        self
    }

    /// Build one worker's layer for a `(workers, rank)` comm topology.
    ///
    /// Gate weights are derived from `seed` only (identical on every
    /// worker — they are `world`-tagged); expert weights from
    /// `(seed, rank)`.  Both derivations are bit-identical to the seed
    /// system's `DistMoeLayer::init`.
    pub fn build(
        &self,
        rt: Arc<Runtime>,
        workers: usize,
        rank: usize,
    ) -> Result<DistMoeLayer> {
        let g = probe_geometry(&rt, workers)?;
        let ne_global = workers * g.ne_local;
        if self.comm.grad_overlap && self.comm.grad_shard == "zero" {
            return Err(Error::Config(
                "comm.grad_shard = \"zero\" is already a bucketed \
                 nonblocking schedule — turn grad_overlap off"
                    .into(),
            ));
        }

        let mut gate_rng = Rng::new(self.seed ^ 0x6a7e);
        let mut wg = TensorF32::zeros(&[g.dm, ne_global]);
        gate_rng.fill_normal(&mut wg.data, 0.02);
        let bg = TensorF32::zeros(&[ne_global]);

        let expert: Box<dyn ExpertShard> = Box::new(FfnExpertShard::init(
            rt.clone(),
            g.ne_local,
            g.dm,
            g.dh,
            g.buckets.clone(),
            self.seed,
            rank,
        ));
        let gate = gate::from_config(&self.cfg, self.seed)?;
        // the node topology orders the pipelined exchange's chunks
        // most-local-first; flat (the default) reproduces the ring
        // schedule bit-for-bit.  The *collective* policy (hier a2a /
        // tree all-reduce) lives on the comm wrapper (`TopoComm`), not
        // here — the layer is generic over whichever comm it is fed.
        let topo = self.comm.topology_for(workers)?;
        let chunk_policy =
            ChunkPolicy::parse(&self.comm.chunk_policy).ok_or_else(|| {
                Error::Config(format!(
                    "comm.chunk_policy: unknown policy `{}`",
                    self.comm.chunk_policy
                ))
            })?;

        Ok(DistMoeLayer {
            rt,
            workers,
            rank,
            ne_local: g.ne_local,
            k: g.k,
            nb: g.nb,
            dm: g.dm,
            dh: g.dh,
            buckets: g.buckets,
            wg,
            bg,
            gate,
            expert,
            overlap: self.comm.overlap,
            chunks: if self.comm.chunks == 0 {
                0 // adaptive; resolved per step from AdaptState
            } else {
                self.comm.chunks.clamp(1, workers)
            },
            grad_overlap: self.comm.grad_overlap,
            grad_shard: self.comm.grad_shard == "zero",
            topo,
            chunk_policy,
            balance_coef: self.cfg.balance_coef as f32,
            pool: Mutex::new(BufferPool::new(self.comm.pool)),
            adapt: Mutex::new(AdaptState {
                chunks: CommConfig::default().chunks.clamp(1, workers),
                my_ratio: -1.0,
            }),
            placement: PlacementPlan::seed(workers, g.ne_local),
            shadow: Mutex::new(None),
            shadow_groups: Vec::new(),
            masked: Vec::new(),
            drained: false,
        })
    }

    /// Convenience: build for an existing comm handle's topology.
    pub fn build_for(
        &self,
        rt: Arc<Runtime>,
        comm: &impl Comm,
    ) -> Result<DistMoeLayer> {
        self.build(rt, comm.size(), comm.rank())
    }
}

/// A host rank's shadow-replica state (placement policy `shadow`).
///
/// Replica `i` of this rank's hosted list computes in extended
/// dispatch slot `ne_local + i`, on slot `i` of a second expert shard.
/// The authoritative parameter copies are the *slice tensors* in
/// `params` (4 per hosted expert, in [`ExpertShard::params`] slot
/// order); `opt` is a real [`Adam`] over those slices whose moments
/// were transferred from the owner and whose `step`/`lr` mirror the
/// owner's optimiser each step — so a replica's update is the owner's
/// update, bit for bit, and the shard tensors are refreshed from the
/// slices after each step.
struct ShadowStore {
    shard: FfnExpertShard,
    params: Vec<TensorF32>,
    opt: Adam,
}

/// Per-worker gate parameters + pluggable gate/expert modules for one
/// MoE layer.
pub struct DistMoeLayer {
    rt: Arc<Runtime>,
    pub workers: usize,
    pub rank: usize,
    pub ne_local: usize,
    pub k: usize,
    pub nb: usize,
    pub dm: usize,
    /// Expert hidden width from the manifest (FFN shard geometry; kept
    /// on the layer because the fused comparison artifacts share it).
    pub dh: usize,
    buckets: Vec<usize>,
    // replicated gate GEMM parameters (tag: world)
    pub wg: TensorF32,
    pub bg: TensorF32,
    gate: Box<dyn Gate>,
    expert: Box<dyn ExpertShard>,
    /// Pipeline the exchanges against expert compute (`[comm] overlap`).
    pub overlap: bool,
    /// Ring-offset peer chunks per exchange (clamped to `workers`);
    /// `0` = adaptive from the previous step's wire:compute ratio.
    pub chunks: usize,
    /// Fly the replicated gate-grad bucket during the expert backward
    /// (`[comm] grad_overlap`): the backward returns `dwg`/`dbg`
    /// already world-averaged, flagged by `LayerGrads::gate_synced`.
    pub grad_overlap: bool,
    /// ZeRO-shard the replicated gate's optimizer state (`[comm]
    /// grad_shard = "zero"`): the trainer steps the gate through
    /// [`Self::apply_grads_zero`] — reduce-scatter, shard-local Adam
    /// on the owned slice, all-gather of the updated params — instead
    /// of the blocking grad reduce + full-state Adam.  Expert shards
    /// keep full state (their grads are already local-final).
    pub grad_shard: bool,
    /// Node topology of the worker world (`[comm] topology/nodes/
    /// local_size`): orders the pipelined exchange's chunks
    /// most-local-first.  Flat = the ring schedule, bit-for-bit.
    topo: Topology,
    /// How ranks agree the adaptive chunk count from their exchanged
    /// ratios (`[comm] chunk_policy`): mean, or straggler-aware max.
    chunk_policy: ChunkPolicy,
    /// GShard balance-loss gradient weight (`[moe] balance_coef`).
    balance_coef: f32,
    /// Step-persistent buffer arena (`[comm] pool`): padded batches,
    /// cotangent containers and per-peer wire staging recycle across
    /// steps instead of reallocating.  Mutex only for `&self` access —
    /// a layer is driven by its one worker thread.
    pool: Mutex<BufferPool>,
    /// Adaptive chunk-count agreement (`[comm] chunks = 0`).
    adapt: Mutex<AdaptState>,
    /// Where every global expert lives (owner + shadow replicas).
    /// Starts as the seed layout; mutated only by
    /// [`Self::apply_delta`] at step boundaries.  While it *is* the
    /// seed layout, dispatch takes the historical
    /// `DispatchPlan::build` path, bit for bit.
    placement: PlacementPlan,
    /// This rank's shadow-replica params/optimiser, when it hosts any.
    /// Mutex for `&self` access in the forward (one worker thread).
    shadow: Mutex<Option<ShadowStore>>,
    /// One grad-sync sub-group per shadowed expert this rank
    /// participates in (owner or host), ascending expert order —
    /// rebuilt on every applied delta, on all member ranks at the same
    /// drained step boundary (their tag namespaces restart together).
    shadow_groups: Vec<(usize, ProcessGroup)>,
    /// Degraded mode (`[fault] recover = "degrade"`): per-global-expert
    /// score mask, set by [`Self::fail_rank`] on *every* rank for the
    /// quarantined rank's shadow-uncovered experts.  Masked experts'
    /// gate scores are floored to `-1e30` before routing (not `-inf` —
    /// a softmax row of `-inf` NaNs under max-subtraction), so the gate
    /// steers tokens away identically everywhere and its balance loss
    /// keeps pushing load off them.  Empty = healthy.
    masked: Vec<bool>,
    /// Set on the quarantined rank itself: its own batch's assignment
    /// weights are zeroed after routing, so the zombie's tokens transit
    /// the (still world-sized, lockstep) exchange but contribute zero
    /// output, zero loss and zero gradient.
    drained: bool,
}

/// Forward residuals needed by the backward chain.
pub struct MoeLayerState {
    pub assign: GateAssign,
    pub plan: DispatchPlan,
    pub eb: ExpertBatch,
    /// Expert outputs in packed slot order (combine input), saved for
    /// combine_bwd.
    pub y_slots: TensorF32,
    /// This worker's token features (gate_bwd + scatter transpose).
    pub x: TensorF32,
    /// Per-global-expert counts this worker routed (load monitor food;
    /// shared with `plan.counts_global`).  Counts every assignment
    /// slot, including zero-weight drops/fillers, because every slot
    /// transits the exchange.
    pub counts_global: Vec<u32>,
    /// Per-global-expert counts of *kept* (weight > 0) assignments —
    /// the histogram load metrics should use.  Identical to
    /// `counts_global` for gates that never zero-weight.
    pub counts_kept: Vec<u32>,
    /// GShard auxiliary balance loss of this iteration's routing
    /// (over the kept counts).
    pub balance: f64,
}

/// Gradients produced by the backward pass.
pub struct LayerGrads {
    pub dx: TensorF32,
    pub dwg: TensorF32,
    pub dbg: TensorF32,
    /// Expert-shard gradients as named slots, in
    /// [`ExpertShard::params`] order.
    pub expert: Vec<(&'static str, TensorF32)>,
    /// `dwg`/`dbg` are already world-averaged: the backward flew the
    /// gate-grad bucket during the expert backward (`[comm]
    /// grad_overlap`), so the trainer must not reduce them again.
    pub gate_synced: bool,
}

impl LayerGrads {
    /// Look an expert gradient up by slot name.
    pub fn expert_grad(&self, name: &str) -> Option<&TensorF32> {
        self.expert.iter().find(|(n, _)| *n == name).map(|(_, t)| t)
    }
}

impl DistMoeLayer {
    /// Seed-compatible shorthand: default top-k softmax gate + FFN
    /// shard, weights derived exactly as the pre-trait layer did.
    pub fn init(
        rt: Arc<Runtime>,
        workers: usize,
        rank: usize,
        seed: u64,
    ) -> Result<DistMoeLayer> {
        MoeLayerBuilder::new().seed(seed).build(rt, workers, rank)
    }

    /// The routing policy this layer was built with.
    pub fn gate(&self) -> &dyn Gate {
        self.gate.as_ref()
    }

    /// The expert shard this layer was built with.
    pub fn expert(&self) -> &dyn ExpertShard {
        self.expert.as_ref()
    }

    /// The node topology the chunk schedule is ordered by.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// All trainable parameters as named slots: gate GEMM first
    /// (`wg`, `bg`), then the expert shard's slots.
    pub fn params(&self) -> Vec<(&'static str, &TensorF32)> {
        let mut v = vec![("wg", &self.wg), ("bg", &self.bg)];
        v.extend(self.expert.params());
        v
    }

    /// Mutable view of [`Self::params`], same slot order — the
    /// checkpoint-restore entry (the trainers land saved tensors here).
    pub fn params_mut(&mut self) -> Vec<(&'static str, &mut TensorF32)> {
        let mut v: Vec<(&'static str, &mut TensorF32)> =
            vec![("wg", &mut self.wg), ("bg", &mut self.bg)];
        v.extend(self.expert.params_mut());
        v
    }

    /// Apply one optimiser step over all layer parameters from a
    /// backward pass's gradients (same slot order as [`Self::params`]).
    pub fn apply_grads(&mut self, opt: &mut Adam, grads: &LayerGrads) -> Result<()> {
        {
            let pnames: Vec<&str> = self.expert.params().iter().map(|(n, _)| *n).collect();
            let gnames: Vec<&str> = grads.expert.iter().map(|(n, _)| *n).collect();
            if pnames != gnames {
                return Err(Error::Shape(format!(
                    "expert grad slots {gnames:?} do not match params {pnames:?}"
                )));
            }
        }
        let mut gs: Vec<&TensorF32> = vec![&grads.dwg, &grads.dbg];
        gs.extend(grads.expert.iter().map(|(_, g)| g));
        let mut ps: Vec<&mut TensorF32> = vec![&mut self.wg, &mut self.bg];
        ps.extend(self.expert.params_mut().into_iter().map(|(_, t)| t));
        opt.update_refs(&mut ps, &gs)
    }

    /// The ZeRO optimiser step ([`Self::grad_shard`]): the *raw* gate
    /// grads ride one fused schedule — reduce-scatter so each rank's
    /// owned slice is fully summed, scale + shard-local Adam on that
    /// slice only, then all-gather of the **updated gate params** —
    /// while the expert slots step locally with full state (their
    /// grads are already final).  Replaces the trainer's blocking
    /// gate reduce *and* [`Self::apply_grads`]; `opt` must hold
    /// shard-sized state for slots 0/1 (see
    /// [`MoeLayerTrainer::new`](super::MoeLayerTrainer)).  Bit-identical
    /// to the replicated path: the shard's partial sums match the
    /// blocking ring's by construction, and Adam's recurrence is
    /// per-element.
    pub fn apply_grads_zero(
        &mut self,
        comm: &mut impl Comm,
        opt: &mut Adam,
        grads: &LayerGrads,
    ) -> Result<()> {
        {
            let pnames: Vec<&str> = self.expert.params().iter().map(|(n, _)| *n).collect();
            let gnames: Vec<&str> = grads.expert.iter().map(|(n, _)| *n).collect();
            if pnames != gnames {
                return Err(Error::Shape(format!(
                    "expert grad slots {gnames:?} do not match params {pnames:?}"
                )));
            }
        }
        if grads.gate_synced {
            return Err(Error::Config(
                "apply_grads_zero: gate grads arrived pre-averaged \
                 (grad_overlap) — the zero schedule needs the raw sums"
                    .into(),
            ));
        }
        opt.begin_step();
        let bufs = vec![grads.dwg.data.clone(), grads.dbg.data.clone()];
        let mut pending = comm.all_reduce_zero(bufs)?;
        let scale = 1.0 / self.workers as f32;
        for (j, p) in [&mut self.wg, &mut self.bg].into_iter().enumerate() {
            let (range, buf) = pending.wait_bucket_shard(comm, j)?;
            if opt.shard.get(j) != Some(&Some(range.clone())) {
                return Err(Error::Config(format!(
                    "apply_grads_zero: slot {j} optimizer shard {:?} != comm \
                     shard {range:?} (layer topology vs comm backend mismatch?)",
                    opt.shard.get(j)
                )));
            }
            if self.workers > 1 {
                for x in buf[range.clone()].iter_mut() {
                    *x *= scale;
                }
            }
            opt.update_shard(j, &mut p.data[range.clone()], &buf[range.clone()])?;
            buf[range.clone()].copy_from_slice(&p.data[range]);
            p.data = pending.gather_bucket(comm, j)?;
        }
        for (i, (_, t)) in self.expert.params_mut().into_iter().enumerate() {
            opt.update_slot(2 + i, t, &grads.expert[i].1)?;
        }
        Ok(())
    }

    /// Pre-compile every stage executable this layer can touch.
    pub fn warm(&self) -> Result<()> {
        self.rt.executable(&format!("gate_fwd_w{}", self.workers))?;
        self.rt.executable(&format!("gate_bwd_w{}", self.workers))?;
        self.rt.executable("combine_fwd")?;
        self.rt.executable("combine_bwd")?;
        self.expert.warm()
    }

    /// Matmul FLOPs this worker performed for `state` (fig-6 metric):
    /// gate GEMM + the expert shard over real (unpadded) rows.
    pub fn flops(&self, state: &MoeLayerState) -> f64 {
        let gate = 2.0 * self.nb as f64 * self.dm as f64
            * (self.workers * self.ne_local) as f64;
        let rows: usize = state.eb.rows_per_expert.iter().sum();
        gate + self.expert.flops(rows)
    }

    /// The exchange schedule of the next collective: `(pipelined,
    /// chunks)`.  Identical on every rank by construction — the
    /// decision depends only on shared config and the adaptively
    /// *agreed* chunk count (never on local measurements directly),
    /// because the chunk schedule and its tag reservations are wire
    /// protocol.
    fn sched(&self) -> (bool, usize) {
        // Shadow replicas widen the dispatch slot space past ne_local;
        // the chunked pipeline hardwires ne_local-arity count frames,
        // so shadowed steps run the blocking placed path.  Migrated
        // (owner-permuted, shadow-free) plans keep width == ne_local
        // and stay fully pipelineable.
        if !self.overlap || self.workers <= 1 || self.placement.has_shadows() {
            return (false, 1);
        }
        if self.chunks == 0 {
            // adaptive: stay on the pipelined path even at 1 chunk so
            // the ratio exchange keeps flowing and can raise it again
            let c = self.adapt.lock().unwrap().chunks.clamp(1, self.workers);
            (true, c)
        } else {
            let c = self.chunks.clamp(1, self.workers);
            (c > 1, c)
        }
    }

    /// Pool-counter deltas of one forward/backward, surfaced through
    /// the step counters so benches and regression tests see them.
    fn report_pool(&self, start: &PoolStats, counters: &mut Counters) {
        let d = self.pool.lock().unwrap().stats().since(start);
        counters.add("pool_hits", d.hits);
        counters.add("pool_misses", d.misses);
        counters.add("pool_alloc_bytes", d.alloc_bytes);
    }

    /// Hand the backend's spent send buffers back to the wire role
    /// (counts and row payloads alike — see the [`ROLE_WIRE`] note).
    fn drain_spent(&self, comm: &mut impl Comm, pool: &mut BufferPool) {
        pool.give_all(ROLE_WIRE, comm.reclaim_spent());
    }

    /// Recycle consumed *received* buffers: offer them to the backend's
    /// receive freelist first ([`Comm::recycle`] — the TCP frame
    /// readers draw from it, keeping the receive path allocation-free),
    /// and pool whatever the backend declines (the thread backend
    /// declines everything: its received buffers are the peers' send
    /// staging, which must return to the arena to keep it miss-free).
    fn repool_wire(
        &self,
        comm: &mut impl Comm,
        pool: &mut BufferPool,
        bufs: impl IntoIterator<Item = Vec<f32>>,
    ) {
        pool.give_all(ROLE_WIRE, comm.recycle(bufs.into_iter().collect()));
    }

    /// Start the overlapped world-average of the replicated gate grads
    /// (`[comm] grad_overlap`): both tensors fly as one bucket launch —
    /// each through its own ring, the same per-tensor decomposition the
    /// trainer's blocking reduction uses, so the bits cannot change.
    /// The rings' round-0 frames travel during the expert backward;
    /// the remaining rounds complete in [`Self::finish_gate_sync`]
    /// (rounds advance inside waits, one outstanding round per ring).
    fn start_gate_sync(
        &self,
        comm: &mut impl Comm,
        dwg: &mut TensorF32,
        dbg: &mut TensorF32,
    ) -> Result<Option<PendingAllReduce>> {
        if !self.grad_overlap || self.workers <= 1 {
            return Ok(None);
        }
        let bufs = vec![
            std::mem::take(&mut dwg.data),
            std::mem::take(&mut dbg.data),
        ];
        Ok(Some(comm.all_reduce_start(bufs)?))
    }

    /// Complete the overlapped gate-grad sync and apply the `1/workers`
    /// average (identical op order to the trainer's blocking path).
    /// Returns whether the grads are now synced.
    fn finish_gate_sync(
        &self,
        comm: &mut impl Comm,
        pending: Option<PendingAllReduce>,
        dwg: &mut TensorF32,
        dbg: &mut TensorF32,
    ) -> Result<bool> {
        let Some(pending) = pending else { return Ok(false) };
        let mut bufs = pending.finish(comm)?;
        dbg.data = bufs.pop().expect("dbg bucket");
        dwg.data = bufs.pop().expect("dwg bucket");
        let scale = 1.0 / self.workers as f32;
        for v in dwg.data.iter_mut() {
            *v *= scale;
        }
        for v in dbg.data.iter_mut() {
            *v *= scale;
        }
        Ok(true)
    }

    /// Current pool counters (cumulative over the layer's lifetime).
    pub fn pool_stats(&self) -> PoolStats {
        self.pool.lock().unwrap().stats()
    }

    /// Return a finished step's step-persistent buffers — the padded
    /// expert batch and the packed combine input — to the arena, so
    /// the next iteration reuses them instead of allocating.  Call
    /// after the backward pass is done with `state` (the trainer does).
    pub fn recycle(&self, state: MoeLayerState) {
        let mut pool = self.pool.lock().unwrap();
        pool.give_tensor(ROLE_BATCH, state.eb.xs);
        pool.give_tensor(ROLE_PACKED, state.y_slots);
    }

    /// Forward-only entry for the serving path: [`Self::forward`] with
    /// the step residuals recycled immediately instead of carried into
    /// a backward pass.  No cotangent containers are ever drawn (the
    /// grad-side pool roles stay untouched), so a resident inference
    /// daemon reuses exactly two step-persistent buffers per step and
    /// never grows the arena with training-only state.
    pub fn forward_infer(
        &self,
        comm: &mut impl Comm,
        x: TensorF32,
        counters: &mut Counters,
    ) -> Result<TensorF32> {
        let (y, state) = self.forward(comm, x, counters)?;
        self.recycle(state);
        Ok(y)
    }

    /// Forward pass over this worker's `x: [nb, dm]`.
    ///
    /// `counters` records exchange volumes (`moe_a2a_bytes`), host row
    /// copies (`moe_copy_bytes`) and pool traffic (`pool_*`) for the
    /// net model.  With `[comm] overlap` the phase-1 count exchange is
    /// folded into chunk 0's flight and the phase-2 exchange runs
    /// pipelined against the expert shard
    /// ([`Self::dispatch_compute_overlapped`]); outputs are
    /// bit-identical either way.
    pub fn forward(
        &self,
        comm: &mut impl Comm,
        x: TensorF32,
        counters: &mut Counters,
    ) -> Result<(TensorF32, MoeLayerState)> {
        let pool_start = self.pool.lock().unwrap().stats();
        // ---- gate scores (L1 kernel via HLO) ----
        let gate = self.rt.executable(&format!("gate_fwd_w{}", self.workers))?;
        let out = gate.run_refs(&[(&x).into(), (&self.wg).into(), (&self.bg).into()])?;
        let mut scores = out.into_iter().next().unwrap().into_f32()?;

        // ---- degraded-mode quarantine (see `crate::fault`) ----
        // Uncovered experts of a down rank vanish from routing on every
        // rank identically: their scores are floored so the gate never
        // assigns them (and its balance loss steers load away).
        if self.masked.iter().any(|&m| m) {
            let ne_global = self.workers * self.ne_local;
            for row in scores.data.chunks_mut(ne_global) {
                for (e, &m) in self.masked.iter().enumerate() {
                    if m {
                        row[e] = -1e30;
                    }
                }
            }
        }

        // ---- host gating + plan (the paper's "local shuffle") ----
        let mut assign = self.gate.route(&scores, self.k)?;
        if self.drained {
            // the zombie's own batch is weightless: its rows still ride
            // the world-sized exchange (lockstep), but contribute zero
            // output and zero gradient everywhere
            for w in assign.w.iter_mut() {
                *w = 0.0;
            }
        }
        let plan = if self.placement.is_seed() {
            // the historical static plan, bit for bit
            DispatchPlan::build(&assign, self.workers, self.ne_local)?
        } else {
            // placement-aware: each expert's tokens go to its nearest
            // replica; the slot space widens by the shadow width
            let width = self.ne_local + self.placement.shadow_width();
            DispatchPlan::build_routed(&assign, self.workers, self.ne_local, width, |e| {
                self.placement.route(e, self.rank)
            })?
        };

        let (pipelined, chunks) = self.sched();
        let (eb, y_slots) = if self.placement.has_shadows() {
            self.dispatch_compute_placed(comm, &plan, &x, counters)?
        } else if pipelined {
            self.dispatch_compute_overlapped(comm, &plan, &x, chunks, counters)?
        } else {
            self.dispatch_compute_blocking(comm, &plan, &x, counters)?
        };

        let combine = self.rt.executable("combine_fwd")?;
        let w_t = TensorF32::from_vec(&[self.nb, self.k], assign.w.clone())?;
        let slots = plan.slots_i32();
        let out = combine.run_refs(&[
            (&y_slots).into(),
            (&slots).into(),
            (&w_t).into(),
        ])?;
        let y = out.into_iter().next().unwrap().into_f32()?;
        self.report_pool(&pool_start, counters);

        // ---- per-step routing metrics (monitor food) ----
        // Load metrics count only kept (weight > 0) assignments so
        // capacity gates' zero-weight drop/filler slots don't read as
        // phantom load; the dispatch histogram keeps counting them
        // because they really transit the exchange.
        let counts_kept = assign.kept_counts(self.workers * self.ne_local);
        let balance = match &assign.probs {
            Some(p) => balance_loss(&counts_kept, p),
            None => {
                let mut p = scores.clone();
                ops::softmax_rows(&mut p)?;
                balance_loss(&counts_kept, &p)
            }
        };
        let counts_global = plan.counts_global.clone();

        Ok((
            y,
            MoeLayerState { assign, plan, eb, y_slots, x, counts_global, counts_kept, balance },
        ))
    }

    /// Figure-2 phases 1+2 + expert execution, blocking — the seed
    /// schedule, now staged through the buffer pool: the count round,
    /// then the full exchange strictly before one full-bucket expert
    /// call.
    fn dispatch_compute_blocking(
        &self,
        comm: &mut impl Comm,
        plan: &DispatchPlan,
        x: &TensorF32,
        counters: &mut Counters,
    ) -> Result<(ExpertBatch, TensorF32)> {
        let mut pool = self.pool.lock().unwrap();

        // ---- Figure 2 phase 1: exchange per-expert counts (pooled
        // staging, like every other buffer on the hot path) ----
        let count_bufs: Vec<Vec<f32>> = plan
            .send_counts
            .iter()
            .map(|c| {
                let mut b = pool.take_vec(ROLE_WIRE, c.len());
                b.extend(c.iter().map(|&x| x as f32));
                b
            })
            .collect();
        let t = Instant::now();
        let recv_count_bufs = comm.all_to_all_v(count_bufs)?;
        counters.add("phase_dispatch_ns", t.elapsed().as_nanos() as u64);
        self.drain_spent(comm, &mut pool);
        let recv_counts: Vec<Vec<u32>> = recv_count_bufs
            .iter()
            .map(|b| b.iter().map(|&x| x as u32).collect())
            .collect();
        self.repool_wire(comm, &mut pool, recv_count_bufs);

        // ---- Figure 2 phase 2, strictly before the expert shard ----
        let send = plan.pack_into(x, &mut pool, ROLE_WIRE)?;
        let sent_bytes: usize = send.iter().map(|b| b.len() * 4).sum();
        counters.add("moe_a2a_bytes", sent_bytes as u64);
        counters.add("moe_copy_bytes", sent_bytes as u64);
        let t = Instant::now();
        let recv = comm.all_to_all_v(send)?;
        counters.add("phase_dispatch_ns", t.elapsed().as_nanos() as u64);
        self.drain_spent(comm, &mut pool);

        let mut eb = ExpertBatch::shell_pooled(
            recv_counts,
            self.ne_local,
            self.dm,
            &self.buckets,
            &mut pool,
            ROLE_BATCH,
        )?;
        let mut copied = 0u64;
        for (p, part) in recv.iter().enumerate() {
            copied += eb.fill_peer(p, part)? as u64;
        }
        self.repool_wire(comm, &mut pool, recv);
        counters.add("moe_copy_bytes", copied);
        counters.add("moe_bucket_rows", (eb.bucket * eb.ne_local) as u64);
        counters.add(
            "moe_real_rows",
            eb.rows_per_expert.iter().sum::<usize>() as u64,
        );
        let t = Instant::now();
        let ys = self.expert.forward(&eb)?;
        counters.add("phase_compute_ns", t.elapsed().as_nanos() as u64);
        let ret = eb.split_outputs_pooled(&ys, &mut pool, ROLE_WIRE)?;
        let ret_bytes: usize = ret.iter().map(|b| b.len() * 4).sum();
        counters.add("moe_a2a_bytes", ret_bytes as u64);
        counters.add("moe_copy_bytes", ret_bytes as u64);
        let t = Instant::now();
        let back = comm.all_to_all_v(ret)?;
        counters.add("phase_combine_ns", t.elapsed().as_nanos() as u64);
        self.drain_spent(comm, &mut pool);
        let mut y_slots = pool.take_tensor_filled(ROLE_PACKED, &[self.nb * self.k, self.dm])?;
        let unpacked = plan.unpack_returned_into(&back, self.dm, &mut y_slots)?;
        self.repool_wire(comm, &mut pool, back);
        counters.add("moe_copy_bytes", unpacked as u64);
        Ok((eb, y_slots))
    }

    /// The blocking schedule over a shadow-widened slot space
    /// (placement policy `shadow`): every peer frame carries
    /// `ne_local + shadow_width` slots — the native experts first, then
    /// this rank's hosted replicas.  Arriving buffers split at the
    /// native row boundary into the main batch and a second
    /// replica batch computed on the shadow shard; returns concatenate
    /// per peer in the same slot order, so the sender's
    /// `unpack_returned_into` sees exactly the layout its routed plan
    /// promised.  The main batch is the step residual; the replica
    /// batch dies here (the backward re-dispatches against owners).
    fn dispatch_compute_placed(
        &self,
        comm: &mut impl Comm,
        plan: &DispatchPlan,
        x: &TensorF32,
        counters: &mut Counters,
    ) -> Result<(ExpertBatch, TensorF32)> {
        let width = self.ne_local + self.placement.shadow_width();
        let hosted = self.placement.hosted(self.rank).len();
        let mut pool = self.pool.lock().unwrap();

        // ---- phase 1: widened per-slot counts ----
        let count_bufs: Vec<Vec<f32>> = plan
            .send_counts
            .iter()
            .map(|c| {
                let mut b = pool.take_vec(ROLE_WIRE, c.len());
                b.extend(c.iter().map(|&x| x as f32));
                b
            })
            .collect();
        let recv_count_bufs = comm.all_to_all_v(count_bufs)?;
        self.drain_spent(comm, &mut pool);
        // split each width-wide count frame at ne_local: native prefix
        // → main batch; shadow suffix (padded back to ne_local arity —
        // a rank hosts at most ne_local replicas) → replica batch
        let mut native_counts: Vec<Vec<u32>> = Vec::with_capacity(self.workers);
        let mut shadow_counts: Vec<Vec<u32>> = Vec::with_capacity(self.workers);
        for b in &recv_count_bufs {
            if b.len() != width {
                return Err(Error::Shape(format!(
                    "placed count frame arity {} != {width}",
                    b.len()
                )));
            }
            native_counts.push(b[..self.ne_local].iter().map(|&v| v as u32).collect());
            let mut sc: Vec<u32> = b[self.ne_local..].iter().map(|&v| v as u32).collect();
            sc.resize(self.ne_local, 0);
            shadow_counts.push(sc);
        }
        self.repool_wire(comm, &mut pool, recv_count_bufs);

        // ---- phase 2: rows, ordered by extended slot per peer ----
        let send = plan.pack_into(x, &mut pool, ROLE_WIRE)?;
        let sent_bytes: usize = send.iter().map(|b| b.len() * 4).sum();
        counters.add("moe_a2a_bytes", sent_bytes as u64);
        counters.add("moe_copy_bytes", sent_bytes as u64);
        let t = Instant::now();
        let recv = comm.all_to_all_v(send)?;
        counters.add("phase_dispatch_ns", t.elapsed().as_nanos() as u64);
        self.drain_spent(comm, &mut pool);

        let mut eb = ExpertBatch::shell_pooled(
            native_counts,
            self.ne_local,
            self.dm,
            &self.buckets,
            &mut pool,
            ROLE_BATCH,
        )?;
        // non-hosts receive no shadow rows (the plan never routes a
        // replica slot at them), so they skip the replica batch
        let mut sb = if hosted > 0 {
            Some(ExpertBatch::shell_pooled(
                shadow_counts,
                self.ne_local,
                self.dm,
                &self.buckets,
                &mut pool,
                ROLE_SHADOW,
            )?)
        } else {
            None
        };
        let mut copied = 0u64;
        for (p, part) in recv.iter().enumerate() {
            let native_len: usize =
                eb.recv_counts[p].iter().map(|&c| c as usize).sum::<usize>() * self.dm;
            copied += eb.fill_peer(p, &part[..native_len])? as u64;
            if let Some(sb) = sb.as_mut() {
                copied += sb.fill_peer(p, &part[native_len..])? as u64;
            }
        }
        self.repool_wire(comm, &mut pool, recv);
        counters.add("moe_copy_bytes", copied);
        counters.add("moe_bucket_rows", (eb.bucket * eb.ne_local) as u64);
        counters.add(
            "moe_real_rows",
            (eb.rows_per_expert.iter().sum::<usize>()
                + sb.as_ref().map_or(0, |s| s.rows_per_expert.iter().sum::<usize>()))
                as u64,
        );

        // ---- native experts, then this rank's replicas ----
        let t = Instant::now();
        let ys = self.expert.forward(&eb)?;
        counters.add("phase_compute_ns", t.elapsed().as_nanos() as u64);
        let mut ret = eb.split_outputs_pooled(&ys, &mut pool, ROLE_WIRE)?;
        if let Some(sb) = sb.take() {
            let sh_rows: usize = sb.rows_per_expert.iter().sum();
            if sh_rows > 0 {
                let shadow = self.shadow.lock().unwrap();
                let st = shadow.as_ref().ok_or_else(|| {
                    Error::Shape("shadow plan without a shadow store".into())
                })?;
                counters.add("moe_bucket_rows", (sb.bucket * sb.ne_local) as u64);
                let ys_sh = st.shard.forward(&sb)?;
                let ret_sh = sb.split_outputs_pooled(&ys_sh, &mut pool, ROLE_WIRE)?;
                let mut spent = Vec::with_capacity(ret_sh.len());
                for (p, extra) in ret_sh.into_iter().enumerate() {
                    ret[p].extend_from_slice(&extra);
                    spent.push(extra);
                }
                pool.give_all(ROLE_WIRE, spent);
            }
            pool.give_tensor(ROLE_SHADOW, sb.xs);
        }
        let ret_bytes: usize = ret.iter().map(|b| b.len() * 4).sum();
        counters.add("moe_a2a_bytes", ret_bytes as u64);
        counters.add("moe_copy_bytes", ret_bytes as u64);
        let t = Instant::now();
        let back = comm.all_to_all_v(ret)?;
        counters.add("phase_combine_ns", t.elapsed().as_nanos() as u64);
        self.drain_spent(comm, &mut pool);
        let mut y_slots = pool.take_tensor_filled(ROLE_PACKED, &[self.nb * self.k, self.dm])?;
        let unpacked = plan.unpack_returned_into(&back, self.dm, &mut y_slots)?;
        self.repool_wire(comm, &mut pool, back);
        counters.add("moe_copy_bytes", unpacked as u64);
        Ok((eb, y_slots))
    }

    /// Figure-2 phase 2 + expert execution, pipelined (the §4 overlap),
    /// zero-copy edition: the exchange decomposes into ring-offset peer
    /// chunks; while chunk `c`'s rows run through the expert shard,
    /// chunk `c+1`'s tokens are already on the wire, and each chunk's
    /// outputs stream back the moment they exist.  The combine input
    /// `y_slots` and the saved full batch are assembled exactly as the
    /// blocking path assembles them — expert math is row-independent —
    /// so outputs stay bit-identical.
    ///
    /// Three zero-copy properties distinguish this from the PR 2
    /// schedule it replaces:
    ///
    /// * **folded count round** — phase 1 (per-expert counts, plus the
    ///   adaptive-chunking ratio) flies concurrently with chunk 0's
    ///   data instead of as a serial α round before the pipeline;
    /// * **single landing** — arriving rows are copied once, into the
    ///   full-batch residual; each chunk's compute batch is *gathered
    ///   from that buffer* ([`ExpertBatch::chunk_slice`]) into one
    ///   pooled staging whose bucket never exceeds the blocking
    ///   bucket, instead of re-copied from the wire buffers into a
    ///   freshly allocated per-chunk batch;
    /// * **pooled staging** — wire buffers, the residual, and the
    ///   chunk staging all recycle through the arena, so a
    ///   steady-state step allocates nothing.
    fn dispatch_compute_overlapped(
        &self,
        comm: &mut impl Comm,
        plan: &DispatchPlan,
        x: &TensorF32,
        chunks: usize,
        counters: &mut Counters,
    ) -> Result<(ExpertBatch, TensorF32)> {
        let w = self.workers;
        let rank = self.rank;
        let chunks = chunks.clamp(1, w);
        let groups = chunk_peer_groups_topo(rank, &self.topo, chunks);
        counters.add("moe_overlap_chunks", chunks as u64);
        let mut pool = self.pool.lock().unwrap();
        let mut wire_secs = 0f64;
        let mut compute_secs = 0f64;

        let mut send = plan.pack_into(x, &mut pool, ROLE_WIRE)?;
        let sent_bytes: usize = send.iter().map(|b| b.len() * 4).sum();
        counters.add("moe_a2a_bytes", sent_bytes as u64);
        let mut copied = sent_bytes as u64;

        // Tag reservation order is part of the wire protocol: every
        // rank takes 1 + 2·chunks seqs in the same sequence.
        let count_tag = (comm.next_seq() << 8) | 2;
        let disp_tags: Vec<u64> =
            (0..chunks).map(|_| (comm.next_seq() << 8) | 1).collect();
        let ret_tags: Vec<u64> =
            (0..chunks).map(|_| (comm.next_seq() << 8) | 1).collect();

        // ---- folded phase 1: counts (+ adaptive ratio) ride chunk
        // 0's flight instead of a serial round before it ----
        let my_ratio = self.adapt.lock().unwrap().my_ratio;
        for p in 0..w {
            if p != rank {
                let mut buf = pool.take_vec(ROLE_WIRE, self.ne_local + 1);
                buf.extend(plan.send_counts[p].iter().map(|&c| c as f32));
                buf.push(my_ratio);
                comm.isend(p, count_tag, buf)?;
            }
        }

        let mut recv_parts: Vec<Option<Vec<f32>>> = (0..w).map(|_| None).collect();
        let mut back_parts: Vec<Option<Vec<f32>>> = (0..w).map(|_| None).collect();
        let mut disp_pend: Vec<PendingChunk> =
            (0..chunks).map(|_| Vec::new()).collect();
        let mut ret_pend: Vec<PendingChunk> =
            (0..chunks).map(|_| Vec::new()).collect();

        post_chunk(
            comm, rank, &groups[0], disp_tags[0], &mut send, &mut recv_parts,
            &mut disp_pend[0],
        )?;
        self.drain_spent(comm, &mut pool);

        // counts are tiny; they complete while chunk 0's rows fly
        let mut count_peers = Vec::with_capacity(w.saturating_sub(1));
        let mut count_reqs: Vec<CommRequest> = Vec::with_capacity(w.saturating_sub(1));
        for p in 0..w {
            if p != rank {
                count_peers.push(p);
                count_reqs.push(comm.irecv(p, count_tag)?);
            }
        }
        let t = Instant::now();
        let count_datas = comm.wait_all(count_reqs)?;
        wire_secs += t.elapsed().as_secs_f64();
        let mut recv_counts: Vec<Vec<u32>> = vec![Vec::new(); w];
        let mut ratios = vec![-1.0f32; w];
        recv_counts[rank] = plan.send_counts[rank].clone();
        ratios[rank] = my_ratio;
        for (p, data) in count_peers.into_iter().zip(count_datas) {
            let data = data.unwrap_or_default();
            if data.len() != self.ne_local + 1 {
                return Err(Error::Comm(format!(
                    "folded count round: peer {p} sent {} floats, expected {}",
                    data.len(),
                    self.ne_local + 1
                )));
            }
            recv_counts[p] = data[..self.ne_local].iter().map(|&v| v as u32).collect();
            ratios[p] = data[self.ne_local];
            self.repool_wire(comm, &mut pool, [data]);
        }
        // agree on the next step's adaptive chunk count from everyone's
        // ratio (same data, same rank-ordered reduction — mean or the
        // straggler-aware max — on every worker)
        if self.chunks == 0 {
            if let Some(c) = agree_chunks(&ratios, self.chunk_policy, w) {
                self.adapt.lock().unwrap().chunks = c;
            }
        }

        // full-batch residual for the backward pass, filled in place as
        // chunks land (same bucket selection and row layout as the
        // blocking path, so `state.eb` stays bit-identical); this is
        // the rows' *only* landing — chunks compute on slices of it
        let mut eb = ExpertBatch::shell_pooled(
            recv_counts,
            self.ne_local,
            self.dm,
            &self.buckets,
            &mut pool,
            ROLE_BATCH,
        )?;

        for c in 0..chunks {
            // keep the next chunk's tokens in flight through this
            // chunk's expert execution
            if c + 1 < chunks {
                post_chunk(
                    comm, rank, &groups[c + 1], disp_tags[c + 1], &mut send,
                    &mut recv_parts, &mut disp_pend[c + 1],
                )?;
                self.drain_spent(comm, &mut pool);
            }
            let t = Instant::now();
            wait_chunk(comm, std::mem::take(&mut disp_pend[c]), &mut recv_parts)?;
            wire_secs += t.elapsed().as_secs_f64();

            // single landing: this chunk's rows go straight into the
            // full-batch residual, then the wire buffers recycle
            for &p in &groups[c].in_peers {
                let part = recv_parts[p].take().unwrap_or_default();
                copied += eb.fill_peer(p, &part)? as u64;
                self.repool_wire(comm, &mut pool, [part]);
            }
            // slice view: gather the chunk's rows out of the shared
            // buffer into one pooled staging (bucket ≤ the full one)
            let slice = eb.chunk_slice(&groups[c].in_peers, &self.buckets)?;
            debug_assert!(slice.bucket <= eb.bucket);
            let mut staging =
                pool.take_tensor(ROLE_STAGE, &[self.ne_local, slice.bucket, self.dm])?;
            copied += eb.gather_chunk(&slice, &mut staging)? as u64;
            counters.add("moe_bucket_rows", (slice.bucket * self.ne_local) as u64);
            counters.add(
                "moe_real_rows",
                slice.rows_per_expert.iter().sum::<usize>() as u64,
            );
            let eb_c = ExpertBatch::for_compute(
                self.ne_local,
                slice.bucket,
                self.dm,
                staging,
                slice.rows_per_expert.clone(),
            );
            let t = Instant::now();
            let ys_c = self.expert.forward(&eb_c)?;
            compute_secs += t.elapsed().as_secs_f64();
            pool.give_tensor(ROLE_STAGE, eb_c.xs);

            // stream this chunk's outputs straight back
            let (ret_c, ret_copied) =
                slice.split_outputs_pooled(&ys_c, self.dm, &mut pool, ROLE_WIRE)?;
            copied += ret_copied as u64;
            counters.add(
                "moe_a2a_bytes",
                ret_c.iter().map(|b| b.len() * 4).sum::<usize>() as u64,
            );
            let mut ret_abs: Vec<Vec<f32>> = (0..w).map(|_| Vec::new()).collect();
            for (buf, &p) in ret_c.into_iter().zip(&slice.peers) {
                ret_abs[p] = buf;
            }
            post_chunk(
                comm, rank, &groups[c].reversed(), ret_tags[c], &mut ret_abs,
                &mut back_parts, &mut ret_pend[c],
            )?;
            self.drain_spent(comm, &mut pool);
        }
        let t = Instant::now();
        for pend in ret_pend {
            wait_chunk(comm, pend, &mut back_parts)?;
        }
        let ret_wait = t.elapsed().as_secs_f64();
        wire_secs += ret_wait;

        let back: Vec<Vec<f32>> = back_parts
            .into_iter()
            .map(|b| b.unwrap_or_default())
            .collect();
        let mut y_slots = pool.take_tensor_filled(ROLE_PACKED, &[self.nb * self.k, self.dm])?;
        copied += plan.unpack_returned_into(&back, self.dm, &mut y_slots)? as u64;
        self.repool_wire(comm, &mut pool, back);
        counters.add("moe_copy_bytes", copied);

        // feed the measured wire:compute balance into the next step's
        // count round (f32-rounded: what peers will actually receive)
        if self.chunks == 0 {
            let ratio = if compute_secs > 1e-12 {
                (wire_secs / compute_secs) as f32
            } else if wire_secs > 0.0 {
                1e3
            } else {
                -1.0
            };
            self.adapt.lock().unwrap().my_ratio = ratio;
        }
        // scoped phase view of the pipelined step for the calibrator:
        // the pre-return waits are dispatch wire, the return waits are
        // the combine direction, matching the blocking path's split
        counters.add("phase_dispatch_ns", ((wire_secs - ret_wait) * 1e9) as u64);
        counters.add("phase_combine_ns", (ret_wait * 1e9) as u64);
        counters.add("phase_compute_ns", (compute_secs * 1e9) as u64);
        Ok((eb, y_slots))
    }

    /// Gate backward: routing Jacobian + balance-loss gradient + gate
    /// GEMM transpose.  Returns `(dx_from_gate, dwg, dbg)`.
    fn gate_backward(
        &self,
        state: &MoeLayerState,
        dw: &TensorF32,
    ) -> Result<(TensorF32, TensorF32, TensorF32)> {
        let ne_global = self.workers * self.ne_local;
        let mut dscores = self.gate.route_bwd(&state.assign, &dw.data, ne_global)?;
        // auxiliary balance-loss gradient over the *kept* counts (the
        // histogram the forward loss uses), scaled by moe.balance_coef
        self.gate.balance_grad(
            &state.assign,
            &state.counts_kept,
            self.balance_coef,
            &mut dscores,
        );
        let gbwd = self.rt.executable(&format!("gate_bwd_w{}", self.workers))?;
        let out = gbwd.run_refs(&[
            (&state.x).into(),
            (&self.wg).into(),
            (&dscores).into(),
        ])?;
        let mut it = out.into_iter();
        let dx = it.next().unwrap().into_f32()?;
        let dwg = it.next().unwrap().into_f32()?;
        let dbg = it.next().unwrap().into_f32()?;
        Ok((dx, dwg, dbg))
    }

    /// Scatter-transpose `dx[token] += dx_packed[slot(assignment)]` —
    /// one fixed assignment order on both paths, so the k-way f32
    /// additions stay bit-identical regardless of arrival order.
    fn scatter_transpose(
        &self,
        plan: &DispatchPlan,
        dx_packed: &TensorF32,
        dx: &mut TensorF32,
    ) {
        for a in 0..plan.nb * plan.k {
            let token = a / plan.k;
            let s = plan.slots[a] as usize;
            let src = &dx_packed.data[s * self.dm..(s + 1) * self.dm];
            let dst = &mut dx.data[token * self.dm..(token + 1) * self.dm];
            for (d, v) in dst.iter_mut().zip(src) {
                *d += v;
            }
        }
    }

    /// Backward pass: `dy: [nb, dm]` → input + parameter gradients.
    /// With `[comm] overlap` the cotangent exchanges run chunked, the
    /// gate GEMM backward overlapping the dispatch flight
    /// ([`Self::backward_overlapped`]); gradients are bit-identical
    /// either way.
    pub fn backward(
        &self,
        comm: &mut impl Comm,
        state: &MoeLayerState,
        dy: &TensorF32,
        counters: &mut Counters,
    ) -> Result<LayerGrads> {
        let pool_start = self.pool.lock().unwrap().stats();
        let plan = &state.plan;

        // ---- combine backward (L1 transpose) ----
        let cbwd = self.rt.executable("combine_bwd")?;
        let w_t = TensorF32::from_vec(&[self.nb, self.k], state.assign.w.clone())?;
        let slots = plan.slots_i32();
        let out = cbwd.run_refs(&[
            (&state.y_slots).into(),
            (&slots).into(),
            (&w_t).into(),
            dy.into(),
        ])?;
        let mut it = out.into_iter();
        let dys = it.next().unwrap().into_f32()?; // [nb*k, dm] packed order
        let dw = it.next().unwrap().into_f32()?; // [nb, k]

        let (pipelined, chunks) = self.sched();
        let grads = if self.placement.has_shadows() {
            self.backward_placed(comm, state, dys, &dw, counters)?
        } else if pipelined {
            self.backward_overlapped(comm, state, dys, &dw, chunks, counters)?
        } else {
            self.backward_blocking(comm, state, dys, &dw, counters)?
        };
        self.report_pool(&pool_start, counters);
        Ok(grads)
    }

    /// The blocking backward chain (seed schedule), staged through the
    /// buffer pool.
    fn backward_blocking(
        &self,
        comm: &mut impl Comm,
        state: &MoeLayerState,
        dys: TensorF32,
        dw: &TensorF32,
        counters: &mut Counters,
    ) -> Result<LayerGrads> {
        self.backward_core(comm, state, &state.plan, &state.eb, dys, dw, counters)
    }

    /// Backward under shadow replicas.  Replicas are a *forward-only*
    /// acceleration: the backward rebuilds the exact unreplicated
    /// schedule, so every gradient bit matches the never-replicated
    /// run.  Concretely: re-dispatch the saved input rows under the
    /// owner-routed plan to rebuild the owner's full batch, re-pack the
    /// combine cotangents from forward (replica-routed) packed order
    /// into owner packed order, and run the blocking backward core over
    /// them.  Owners end up holding the complete expert gradient;
    /// [`Self::sync_shadows`] then broadcasts those bits to the
    /// replicas so their parameter copies take the identical Adam step.
    fn backward_placed(
        &self,
        comm: &mut impl Comm,
        state: &MoeLayerState,
        dys: TensorF32,
        dw: &TensorF32,
        counters: &mut Counters,
    ) -> Result<LayerGrads> {
        let plan_grad = DispatchPlan::build_routed(
            &state.assign,
            self.workers,
            self.ne_local,
            self.ne_local,
            |e| self.placement.owner(e),
        )?;
        // permute the packed cotangents: forward slot → owner slot
        let n = state.plan.nb * state.plan.k;
        let mut dys_grad = TensorF32::zeros(&[n, self.dm]);
        for a in 0..n {
            let from = state.plan.slots[a] as usize;
            let to = plan_grad.slots[a] as usize;
            dys_grad.data[to * self.dm..(to + 1) * self.dm]
                .copy_from_slice(&dys.data[from * self.dm..(from + 1) * self.dm]);
        }
        // rebuild the unreplicated batch (identical counts, pack order
        // and bucket — the bits the owner's expert backward needs)
        let eb_grad = self.redispatch(comm, &plan_grad, &state.x, counters)?;
        let grads =
            self.backward_core(comm, state, &plan_grad, &eb_grad, dys_grad, dw, counters)?;
        self.pool.lock().unwrap().give_tensor(ROLE_BATCH, eb_grad.xs);
        Ok(grads)
    }

    /// Count + row exchange of the blocking dispatch, without the
    /// compute/return half: rebuilds the receiving batch a plan
    /// implies.  Used by the shadowed backward to reconstruct the
    /// owner-routed batch the forward skipped.
    fn redispatch(
        &self,
        comm: &mut impl Comm,
        plan: &DispatchPlan,
        x: &TensorF32,
        counters: &mut Counters,
    ) -> Result<ExpertBatch> {
        let mut pool = self.pool.lock().unwrap();
        let count_bufs: Vec<Vec<f32>> = plan
            .send_counts
            .iter()
            .map(|c| {
                let mut b = pool.take_vec(ROLE_WIRE, c.len());
                b.extend(c.iter().map(|&x| x as f32));
                b
            })
            .collect();
        let t = Instant::now();
        let recv_count_bufs = comm.all_to_all_v(count_bufs)?;
        counters.add("phase_dispatch_ns", t.elapsed().as_nanos() as u64);
        self.drain_spent(comm, &mut pool);
        let recv_counts: Vec<Vec<u32>> = recv_count_bufs
            .iter()
            .map(|b| b.iter().map(|&x| x as u32).collect())
            .collect();
        self.repool_wire(comm, &mut pool, recv_count_bufs);

        let send = plan.pack_into(x, &mut pool, ROLE_WIRE)?;
        let sent_bytes: usize = send.iter().map(|b| b.len() * 4).sum();
        counters.add("moe_a2a_bytes", sent_bytes as u64);
        counters.add("moe_copy_bytes", sent_bytes as u64);
        let t = Instant::now();
        let recv = comm.all_to_all_v(send)?;
        counters.add("phase_dispatch_ns", t.elapsed().as_nanos() as u64);
        self.drain_spent(comm, &mut pool);

        let mut eb = ExpertBatch::shell_pooled(
            recv_counts,
            self.ne_local,
            self.dm,
            &self.buckets,
            &mut pool,
            ROLE_BATCH,
        )?;
        let mut copied = 0u64;
        for (p, part) in recv.iter().enumerate() {
            copied += eb.fill_peer(p, part)? as u64;
        }
        self.repool_wire(comm, &mut pool, recv);
        counters.add("moe_copy_bytes", copied);
        Ok(eb)
    }

    /// The blocking backward body over an explicit `(plan, eb)` pair —
    /// `state.plan`/`state.eb` on the ordinary path, the rebuilt
    /// owner-routed pair on the shadowed path.  Everything else
    /// (gate backward, overlapped gate sync, cotangent exchanges,
    /// scatter transpose) is byte-for-byte the historical blocking
    /// chain.
    #[allow(clippy::too_many_arguments)]
    fn backward_core(
        &self,
        comm: &mut impl Comm,
        state: &MoeLayerState,
        plan: &DispatchPlan,
        eb: &ExpertBatch,
        dys: TensorF32,
        dw: &TensorF32,
        counters: &mut Counters,
    ) -> Result<LayerGrads> {
        let mut pool = self.pool.lock().unwrap();

        // ---- gate backward: routing Jacobian + gate GEMM ----
        let (mut dx, mut dwg, mut dbg) = self.gate_backward(state, dw)?;
        // overlapped grad sync: the replicated gate-grad bucket departs
        // now and completes after the expert backward, its rounds
        // hiding behind the cotangent exchange and the expert compute
        let gate_sync = self.start_gate_sync(comm, &mut dwg, &mut dbg)?;

        // ---- reverse exchange of output cotangents ----
        // dys is already in packed order; split by destination rows.
        let mut send: Vec<Vec<f32>> = Vec::with_capacity(self.workers);
        let mut pos = 0usize;
        for w in 0..self.workers {
            let rows = plan.send_rows[w];
            let mut buf = pool.take_vec(ROLE_WIRE, rows * self.dm);
            buf.extend_from_slice(&dys.data[pos * self.dm..(pos + rows) * self.dm]);
            send.push(buf);
            pos += rows;
        }
        let sent: usize = send.iter().map(|b| b.len() * 4).sum();
        counters.add("moe_a2a_bytes", sent as u64);
        let mut copied = sent as u64;
        let t = Instant::now();
        let recv = comm.all_to_all_v(send)?;
        counters.add("phase_dispatch_ns", t.elapsed().as_nanos() as u64);
        self.drain_spent(comm, &mut pool);
        let mut dys_in = pool.take_tensor(
            ROLE_COT,
            &[self.ne_local, eb.bucket, self.dm],
        )?;
        copied += eb.rebatch_into(&recv, &mut dys_in)? as u64;
        self.repool_wire(comm, &mut pool, recv);

        // ---- expert shard backward (recompute-style artifact) ----
        let t = Instant::now();
        let (dxs, expert_grads) = self.expert.backward(eb, &dys_in)?;
        counters.add("phase_compute_ns", t.elapsed().as_nanos() as u64);
        pool.give_tensor(ROLE_COT, dys_in);
        let gate_synced = self.finish_gate_sync(comm, gate_sync, &mut dwg, &mut dbg)?;

        // ---- route input cotangents back to token owners ----
        let ret = eb.split_outputs_pooled(&dxs, &mut pool, ROLE_WIRE)?;
        let ret_bytes: usize = ret.iter().map(|b| b.len() * 4).sum();
        counters.add("moe_a2a_bytes", ret_bytes as u64);
        copied += ret_bytes as u64;
        let t = Instant::now();
        let back = comm.all_to_all_v(ret)?;
        counters.add("phase_combine_ns", t.elapsed().as_nanos() as u64);
        self.drain_spent(comm, &mut pool);
        let mut dx_packed =
            pool.take_tensor_filled(ROLE_PACKED, &[self.nb * self.k, self.dm])?;
        copied += plan.unpack_returned_into(&back, self.dm, &mut dx_packed)? as u64;
        self.repool_wire(comm, &mut pool, back);
        counters.add("moe_copy_bytes", copied);

        self.scatter_transpose(plan, &dx_packed, &mut dx);
        pool.give_tensor(ROLE_PACKED, dx_packed);

        Ok(LayerGrads { dx, dwg, dbg, expert: expert_grads, gate_synced })
    }

    /// Backward with comm/compute overlap: every chunk of output
    /// cotangents is queued *before* the gate GEMM backward runs, so
    /// the gate compute hides the dispatch flight; the expert backward
    /// then runs once over the full forward batch (keeping the
    /// parameter-gradient reduction order — and therefore the bits —
    /// identical to blocking), and the input-cotangent returns stream
    /// back per chunk.  All staging is pooled; the cotangent container
    /// and the packed-gradient tensor recycle across steps.
    fn backward_overlapped(
        &self,
        comm: &mut impl Comm,
        state: &MoeLayerState,
        dys: TensorF32,
        dw: &TensorF32,
        chunks: usize,
        counters: &mut Counters,
    ) -> Result<LayerGrads> {
        let plan = &state.plan;
        let w = self.workers;
        let rank = self.rank;
        let chunks = chunks.clamp(1, w);
        let groups = chunk_peer_groups_topo(rank, &self.topo, chunks);
        let offsets = plan.send_offsets();
        counters.add("moe_overlap_chunks", chunks as u64);
        let mut pool = self.pool.lock().unwrap();
        let disp_tags: Vec<u64> =
            (0..chunks).map(|_| (comm.next_seq() << 8) | 1).collect();
        let ret_tags: Vec<u64> =
            (0..chunks).map(|_| (comm.next_seq() << 8) | 1).collect();

        // queue every chunk of packed cotangent rows (pooled staging)
        let sent = plan.nb * plan.k * self.dm * 4;
        counters.add("moe_a2a_bytes", sent as u64);
        let mut copied = sent as u64;
        let mut send: Vec<Vec<f32>> = (0..w)
            .map(|p| {
                let rows = offsets[p + 1] - offsets[p];
                let mut buf = pool.take_vec(ROLE_WIRE, rows * self.dm);
                buf.extend_from_slice(
                    &dys.data[offsets[p] * self.dm..offsets[p + 1] * self.dm],
                );
                buf
            })
            .collect();
        let mut recv_parts: Vec<Option<Vec<f32>>> = (0..w).map(|_| None).collect();
        let mut disp_pend: Vec<PendingChunk> =
            (0..chunks).map(|_| Vec::new()).collect();
        for (c, group) in groups.iter().enumerate() {
            post_chunk(
                comm, rank, group, disp_tags[c], &mut send, &mut recv_parts,
                &mut disp_pend[c],
            )?;
        }
        // push queued frames to the kernel NOW — without this, a
        // deferred-flush backend (TCP) would hold every cotangent in
        // userspace through the gate GEMM and the overlap below would
        // be fictional (the progress engine flushes eagerly anyway)
        comm.flush()?;
        self.drain_spent(comm, &mut pool);

        // gate backward overlaps the cotangent flight
        let (mut dx, mut dwg, mut dbg) = self.gate_backward(state, dw)?;
        // the gate-grad bucket joins the wire now; its rounds complete
        // behind the expert backward below
        let gate_sync = self.start_gate_sync(comm, &mut dwg, &mut dbg)?;

        let t = Instant::now();
        for pend in disp_pend {
            wait_chunk(comm, pend, &mut recv_parts)?;
        }
        counters.add("phase_dispatch_ns", t.elapsed().as_nanos() as u64);
        let recv: Vec<Vec<f32>> = recv_parts
            .into_iter()
            .map(|p| p.unwrap_or_default())
            .collect();
        let mut dys_in = pool.take_tensor(
            ROLE_COT,
            &[self.ne_local, state.eb.bucket, self.dm],
        )?;
        copied += state.eb.rebatch_into(&recv, &mut dys_in)? as u64;
        self.repool_wire(comm, &mut pool, recv);

        // full-batch expert backward: same reduction order as blocking
        let t = Instant::now();
        let (dxs, expert_grads) = self.expert.backward(&state.eb, &dys_in)?;
        counters.add("phase_compute_ns", t.elapsed().as_nanos() as u64);
        pool.give_tensor(ROLE_COT, dys_in);
        let gate_synced = self.finish_gate_sync(comm, gate_sync, &mut dwg, &mut dbg)?;

        // streamed return of input cotangents
        let mut ret = state.eb.split_outputs_pooled(&dxs, &mut pool, ROLE_WIRE)?;
        let ret_bytes: usize = ret.iter().map(|b| b.len() * 4).sum();
        counters.add("moe_a2a_bytes", ret_bytes as u64);
        copied += ret_bytes as u64;
        let mut back_parts: Vec<Option<Vec<f32>>> = (0..w).map(|_| None).collect();
        let mut ret_pend: Vec<PendingChunk> =
            (0..chunks).map(|_| Vec::new()).collect();
        for (c, group) in groups.iter().enumerate() {
            post_chunk(
                comm, rank, &group.reversed(), ret_tags[c], &mut ret,
                &mut back_parts, &mut ret_pend[c],
            )?;
        }
        self.drain_spent(comm, &mut pool);
        let t = Instant::now();
        for pend in ret_pend {
            wait_chunk(comm, pend, &mut back_parts)?;
        }
        counters.add("phase_combine_ns", t.elapsed().as_nanos() as u64);
        let back: Vec<Vec<f32>> = back_parts
            .into_iter()
            .map(|b| b.unwrap_or_default())
            .collect();
        let mut dx_packed =
            pool.take_tensor_filled(ROLE_PACKED, &[self.nb * self.k, self.dm])?;
        copied += plan.unpack_returned_into(&back, self.dm, &mut dx_packed)? as u64;
        self.repool_wire(comm, &mut pool, back);
        counters.add("moe_copy_bytes", copied);
        self.scatter_transpose(plan, &dx_packed, &mut dx);
        pool.give_tensor(ROLE_PACKED, dx_packed);
        Ok(LayerGrads { dx, dwg, dbg, expert: expert_grads, gate_synced })
    }

    // ------------------------------------------------------------------
    // Dynamic placement (see `crate::placement`): the layer executes
    // agreed plan deltas and keeps shadow replicas bit-synchronised.
    // ------------------------------------------------------------------

    /// The current expert layout.
    pub fn placement(&self) -> &PlacementPlan {
        &self.placement
    }

    /// The current adaptive-chunk agreement policy.
    pub fn chunk_policy(&self) -> ChunkPolicy {
        self.chunk_policy
    }

    /// Swap the adaptive-chunk agreement policy (autotune live mode).
    /// Step-boundary safe in lockstep: the policy only shapes how the
    /// *next* ratio exchange is reduced, identically on every rank —
    /// it never touches the wire protocol.
    pub fn set_chunk_policy(&mut self, p: ChunkPolicy) {
        self.chunk_policy = p;
    }

    /// Floats in one expert's parameter slot (all shard tensors).
    fn slot_len(&self) -> usize {
        self.expert
            .params()
            .iter()
            .map(|(_, t)| t.data.len() / self.ne_local)
            .sum()
    }

    /// Wire payload of one expert slot: params, then Adam first and
    /// second moments — the checkpoint slot format, flattened.
    fn pack_slot_state(&self, opt: &Adam, slot: usize) -> Result<Vec<f32>> {
        let ps = self.expert.params();
        let ts: Vec<&TensorF32> = ps.iter().map(|(_, t)| *t).collect();
        let mut payload = pack_expert_slot(&ts, slot)?;
        let ms: Vec<&TensorF32> =
            (0..ts.len()).map(|j| &opt.m[GATE_OPT_SLOTS + j]).collect();
        payload.extend(pack_expert_slot(&ms, slot)?);
        let vs: Vec<&TensorF32> =
            (0..ts.len()).map(|j| &opt.v[GATE_OPT_SLOTS + j]).collect();
        payload.extend(pack_expert_slot(&vs, slot)?);
        Ok(payload)
    }

    /// Inverse of [`Self::pack_slot_state`]: land a migrated expert's
    /// params + Adam moments in local `slot`.
    fn unpack_slot_state(
        &mut self,
        opt: &mut Adam,
        slot: usize,
        payload: &[f32],
    ) -> Result<()> {
        let sl = self.slot_len();
        if payload.len() != 3 * sl {
            return Err(Error::Shape(format!(
                "slot payload {} != {}",
                payload.len(),
                3 * sl
            )));
        }
        let mut ts: Vec<&mut TensorF32> = self
            .expert
            .params_mut()
            .into_iter()
            .map(|(_, t)| t)
            .collect();
        unpack_expert_slot(&payload[..sl], &mut ts, slot)?;
        let p_cnt = ts.len();
        drop(ts);
        let mut ms: Vec<&mut TensorF32> =
            opt.m[GATE_OPT_SLOTS..GATE_OPT_SLOTS + p_cnt].iter_mut().collect();
        unpack_expert_slot(&payload[sl..2 * sl], &mut ms, slot)?;
        let mut vs: Vec<&mut TensorF32> =
            opt.v[GATE_OPT_SLOTS..GATE_OPT_SLOTS + p_cnt].iter_mut().collect();
        unpack_expert_slot(&payload[2 * sl..], &mut vs, slot)?;
        Ok(())
    }

    /// Exchange two local expert slots (params + moments) — the
    /// degenerate migration where one rank owns both experts.
    fn swap_local_slots(&mut self, opt: &mut Adam, sa: usize, sb: usize) -> Result<()> {
        let pa = self.pack_slot_state(opt, sa)?;
        let pb = self.pack_slot_state(opt, sb)?;
        self.unpack_slot_state(opt, sa, &pb)?;
        self.unpack_slot_state(opt, sb, &pa)
    }

    /// Install a received replica on this host: authoritative slice
    /// copies + transferred Adam moments in the replica optimiser, and
    /// the params mirrored into the shadow compute shard's next slot.
    fn install_replica(&mut self, expert: usize, payload: &[f32], lr: f32) -> Result<()> {
        let sl = self.slot_len();
        if payload.len() != 3 * sl {
            return Err(Error::Shape(format!(
                "replica payload {} != {}",
                payload.len(),
                3 * sl
            )));
        }
        // slice shapes = shard param shapes minus the expert dim
        let shapes: Vec<Vec<usize>> = self
            .expert
            .params()
            .iter()
            .map(|(_, t)| t.shape[1..].to_vec())
            .collect();
        let idx = self
            .placement
            .hosted(self.rank)
            .iter()
            .position(|&h| h == expert)
            .ok_or_else(|| Error::Shape("install_replica: not a host".into()))?;
        let shadow = self.shadow.get_mut().unwrap();
        if shadow.is_none() {
            // a second, initially-zero shard: only installed slots
            // ever receive rows, so the other slots' values are inert
            let mut shard = FfnExpertShard::init(
                self.rt.clone(),
                self.ne_local,
                self.dm,
                self.dh,
                self.buckets.clone(),
                0,
                0,
            );
            for (_, t) in shard.params_mut() {
                t.data.fill(0.0);
            }
            *shadow = Some(ShadowStore {
                shard,
                params: Vec::new(),
                opt: Adam::new(&[], lr),
            });
        }
        let st = shadow.as_mut().unwrap();
        st.opt.lr = lr;
        if st.params.len() != idx * shapes.len() {
            return Err(Error::Shape("install_replica: hosting order skew".into()));
        }
        let mut pos = 0usize;
        let mut slices = Vec::with_capacity(shapes.len());
        for shp in &shapes {
            let n: usize = shp.iter().product();
            slices.push(TensorF32::from_vec(shp, payload[pos..pos + n].to_vec())?);
            pos += n;
        }
        for shp in &shapes {
            let n: usize = shp.iter().product();
            st.opt.m.push(TensorF32::from_vec(shp, payload[pos..pos + n].to_vec())?);
            pos += n;
        }
        for shp in &shapes {
            let n: usize = shp.iter().product();
            st.opt.v.push(TensorF32::from_vec(shp, payload[pos..pos + n].to_vec())?);
            pos += n;
        }
        // mirror the params into the compute shard's slot `idx`
        let ne_local = self.ne_local;
        for ((_, dst), src) in st.shard.params_mut().iter_mut().zip(&slices) {
            let stride = dst.data.len() / ne_local;
            dst.data[idx * stride..(idx + 1) * stride].copy_from_slice(&src.data);
        }
        st.params.extend(slices);
        Ok(())
    }

    /// Rebuild this rank's per-expert gradient sub-groups from the
    /// plan.  Runs on every rank after every applied delta — all
    /// members of a group recreate it at the same drained step
    /// boundary, so the restarted tag namespaces stay aligned.
    fn rebuild_shadow_groups(&mut self) -> Result<()> {
        self.shadow_groups.clear();
        for (e, members) in self.placement.shadow_groups() {
            if members.contains(&self.rank) {
                self.shadow_groups
                    .push((e, ProcessGroup::new(members, self.rank, shadow_salt(e))?));
            }
        }
        Ok(())
    }

    /// Execute an agreed [`PlanDelta`] at a step boundary.  Collective:
    /// every rank calls it with the identical delta at the same step,
    /// and world sequence numbers advance uniformly on all ranks even
    /// when only two of them move payload.
    pub fn apply_delta(
        &mut self,
        comm: &mut impl Comm,
        delta: &PlanDelta,
        opt: &mut Adam,
    ) -> Result<()> {
        match *delta {
            PlanDelta::AddShadow { expert, host } => {
                // validate + mutate the plan first (uniform error
                // before any wire traffic), then move the slot
                self.placement.add_shadow(expert, host)?;
                let (orank, oslot) = self.placement.owner(expert);
                let tag = (comm.next_seq() << 8) | PLACE_TAG;
                if self.rank == orank {
                    let payload = self.pack_slot_state(opt, oslot)?;
                    let req = comm.isend(host, tag, payload)?;
                    comm.wait(req)?;
                } else if self.rank == host {
                    let req = comm.irecv(orank, tag)?;
                    let payload = comm
                        .wait(req)?
                        .ok_or_else(|| Error::Comm("empty replica payload".into()))?;
                    self.install_replica(expert, &payload, opt.lr)?;
                }
            }
            PlanDelta::DropShadows => {
                self.placement.clear_shadows();
                *self.shadow.get_mut().unwrap() = None;
            }
            PlanDelta::Swap { a, b } => {
                if self.placement.has_shadows() {
                    return Err(Error::Config(
                        "apply_delta: drop shadows before migrating".into(),
                    ));
                }
                let (ra, sa) = self.placement.owner(a);
                let (rb, sb) = self.placement.owner(b);
                // both transfer directions reserve a seq on every rank
                let tag_a = (comm.next_seq() << 8) | PLACE_TAG;
                let tag_b = (comm.next_seq() << 8) | PLACE_TAG;
                if ra == rb {
                    if self.rank == ra && sa != sb {
                        self.swap_local_slots(opt, sa, sb)?;
                    }
                } else if self.rank == ra {
                    let payload_a = self.pack_slot_state(opt, sa)?;
                    let rx = comm.irecv(rb, tag_b)?;
                    let tx = comm.isend(rb, tag_a, payload_a)?;
                    let payload_b = comm
                        .wait(rx)?
                        .ok_or_else(|| Error::Comm("empty slot payload".into()))?;
                    comm.wait(tx)?;
                    self.unpack_slot_state(opt, sa, &payload_b)?;
                } else if self.rank == rb {
                    let payload_b = self.pack_slot_state(opt, sb)?;
                    let rx = comm.irecv(ra, tag_a)?;
                    let tx = comm.isend(ra, tag_b, payload_b)?;
                    let payload_a = comm
                        .wait(rx)?
                        .ok_or_else(|| Error::Comm("empty slot payload".into()))?;
                    comm.wait(tx)?;
                    self.unpack_slot_state(opt, sb, &payload_a)?;
                }
                self.placement.swap_owners(a, b)?;
            }
        }
        self.rebuild_shadow_groups()
    }

    /// Every-step shadow parameter sync (a no-op without shadows).
    ///
    /// For each shadowed expert — ascending id, identically on every
    /// member — the owner contributes its freshly computed gradient
    /// slot and every replica contributes zeros to the expert's
    /// sub-group all-reduce, i.e. a broadcast of the owner's gradient
    /// bits.  Each replica then applies the owner's exact Adam step
    /// (mirrored `step`/`lr`/`weight_decay` over the transferred
    /// moments) to its authoritative slice copies and refreshes the
    /// compute shard.  Call right after `apply_grads`, on every rank,
    /// every step, so the group collectives stay in lockstep.
    pub fn sync_shadows(
        &mut self,
        comm: &mut impl Comm,
        grads: &LayerGrads,
        opt: &Adam,
    ) -> Result<()> {
        if self.shadow_groups.is_empty() {
            return Ok(());
        }
        let slot_len = self.slot_len();
        let p_cnt = grads.expert.len();
        let rank = self.rank;
        let ne_local = self.ne_local;
        for (e, pg) in self.shadow_groups.iter_mut() {
            let (orank, oslot) = self.placement.owner(*e);
            let mut buf = if rank == orank {
                let gs: Vec<&TensorF32> = grads.expert.iter().map(|(_, g)| g).collect();
                pack_expert_slot(&gs, oslot)?
            } else {
                vec![0.0f32; slot_len]
            };
            pg.bind(comm).all_reduce_sum(&mut buf)?;
            if rank == orank {
                continue; // the owner already stepped in apply_grads
            }
            let idx = self
                .placement
                .hosted(rank)
                .iter()
                .position(|&h| h == *e)
                .ok_or_else(|| Error::Shape("sync_shadows: not a host".into()))?;
            let shadow = self.shadow.get_mut().unwrap();
            let st = shadow
                .as_mut()
                .ok_or_else(|| Error::Shape("sync_shadows: no shadow store".into()))?;
            let ShadowStore { shard, params, opt: sopt } = st;
            sopt.step = opt.step;
            sopt.lr = opt.lr;
            sopt.weight_decay = opt.weight_decay;
            let mut pos = 0usize;
            for j in 0..p_cnt {
                let t = &mut params[idx * p_cnt + j];
                let n = t.data.len();
                let shape = t.shape.clone();
                let g = TensorF32::from_vec(&shape, buf[pos..pos + n].to_vec())?;
                pos += n;
                sopt.update_slot(idx * p_cnt + j, t, &g)?;
            }
            for ((_, dst), src) in
                shard.params_mut().iter_mut().zip(&params[idx * p_cnt..(idx + 1) * p_cnt])
            {
                let stride = dst.data.len() / ne_local;
                dst.data[idx * stride..(idx + 1) * stride].copy_from_slice(&src.data);
            }
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Elastic fault recovery (see `crate::fault`): quarantine a dead
    // rank, route around it, and hand its state back on rejoin.
    // ------------------------------------------------------------------

    /// Per-global-expert quarantine mask (empty = healthy).
    pub fn masked(&self) -> &[bool] {
        &self.masked
    }

    /// Whether this rank is the quarantined zombie.
    pub fn drained(&self) -> bool {
        self.drained
    }

    /// Quarantine `dead` on this rank's view: routing steers to its
    /// experts' live shadow replicas, its *uncovered* experts are
    /// score-masked out of the gate everywhere, and — on the dead rank
    /// itself — the local batch is drained to zero weight.  Called on
    /// **every** rank at the same step boundary with the agreed
    /// membership, so masks, plans and tag schedules stay identical.
    pub fn fail_rank(&mut self, dead: usize) -> Result<()> {
        self.placement.set_down(Some(dead))?;
        let ne_global = self.workers * self.ne_local;
        self.masked = vec![false; ne_global];
        for e in 0..ne_global {
            if self.placement.owner(e).0 == dead
                && self.placement.shadow_hosts(e).is_empty()
            {
                self.masked[e] = true;
            }
        }
        self.drained = self.rank == dead;
        Ok(())
    }

    /// Lift the quarantine (the rejoin epilogue, on every rank).
    pub fn restore_rank(&mut self) -> Result<()> {
        self.placement.set_down(None)?;
        self.masked.clear();
        self.drained = false;
        Ok(())
    }

    /// Wire payload of a *hosted replica's* slot — params then Adam
    /// moments from the [`ShadowStore`]'s authoritative slices, laid
    /// out exactly like [`Self::pack_slot_state`] packs an owned slot,
    /// so the receiver lands it with [`Self::unpack_slot_state`].
    fn pack_replica_slot(&self, expert: usize) -> Result<Vec<f32>> {
        let idx = self
            .placement
            .hosted(self.rank)
            .iter()
            .position(|&h| h == expert)
            .ok_or_else(|| Error::Shape("pack_replica_slot: not a host".into()))?;
        let shadow = self.shadow.lock().unwrap();
        let st = shadow
            .as_ref()
            .ok_or_else(|| Error::Shape("pack_replica_slot: no shadow store".into()))?;
        let p_cnt = self.expert.params().len();
        let mut payload = Vec::with_capacity(3 * self.slot_len());
        for j in 0..p_cnt {
            payload.extend_from_slice(&st.params[idx * p_cnt + j].data);
        }
        for j in 0..p_cnt {
            payload.extend_from_slice(&st.opt.m[idx * p_cnt + j].data);
        }
        for j in 0..p_cnt {
            payload.extend_from_slice(&st.opt.v[idx * p_cnt + j].data);
        }
        Ok(payload)
    }

    /// Rejoin catch-up, live-peer edition: for every expert the down
    /// rank owns that has shadow replicas (which kept training past its
    /// last checkpoint), the lowest-ranked host streams its replica's
    /// params + Adam moments back to the owner slot over `PLACE_TAG`.
    /// Collective like [`Self::apply_delta`]: every rank calls it at
    /// the same boundary and burns one seq per transferred expert, so
    /// world tag namespaces stay aligned; only two ranks move payload.
    /// Call *before* [`Self::restore_rank`] (the down mark selects the
    /// experts).
    pub fn transfer_slots_from_shadows(
        &mut self,
        comm: &mut impl Comm,
        opt: &mut Adam,
    ) -> Result<()> {
        let Some(dead) = self.placement.down() else {
            return Err(Error::Config(
                "transfer_slots_from_shadows: no rank is quarantined".into(),
            ));
        };
        for e in 0..self.placement.ne_global() {
            let (orank, oslot) = self.placement.owner(e);
            if orank != dead {
                continue;
            }
            let hosts = self.placement.shadow_hosts(e);
            let Some(&src) = hosts.first() else { continue };
            let tag = (comm.next_seq() << 8) | PLACE_TAG;
            if self.rank == src {
                let payload = self.pack_replica_slot(e)?;
                let req = comm.isend(dead, tag, payload)?;
                comm.wait(req)?;
            } else if self.rank == dead {
                let req = comm.irecv(src, tag)?;
                let payload = comm.wait(req)?.ok_or_else(|| {
                    Error::Comm("empty replica slot payload".into())
                })?;
                self.unpack_slot_state(opt, oslot, &payload)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_carries_config_overrides() {
        let b = MoeLayerBuilder::new()
            .gate("switch")
            .capacity_factor(1.5)
            .noise_std(0.25)
            .balance_coef(0.02)
            .overlap(true)
            .chunks(8)
            .seed(9);
        assert_eq!(b.cfg.gate, "switch");
        assert!((b.cfg.capacity_factor - 1.5).abs() < 1e-12);
        assert!((b.cfg.noise_std - 0.25).abs() < 1e-12);
        assert!((b.cfg.balance_coef - 0.02).abs() < 1e-12);
        assert!(b.comm.overlap);
        assert_eq!(b.comm.chunks, 8);
        assert_eq!(b.seed, 9);
        // gate selection itself is validated without a runtime
        assert!(gate::from_config(&b.cfg, b.seed).is_ok());
        let bad = MoeLayerBuilder::new().gate("mystery");
        assert!(gate::from_config(&bad.cfg, 0).is_err());
    }

    #[test]
    fn builder_adopts_comm_section() {
        let comm = CommConfig { overlap: true, chunks: 2, ..CommConfig::default() };
        let b = MoeLayerBuilder::new().comm_config(&comm);
        assert_eq!(b.comm, comm);
        // defaults keep the seed-identical blocking schedule, pool on
        let d = MoeLayerBuilder::new();
        assert!(!d.comm.overlap);
        assert!(d.comm.pool);
        // knobs thread through
        let b = MoeLayerBuilder::new().pool(false).chunks(0);
        assert!(!b.comm.pool);
        assert_eq!(b.comm.chunks, 0, "0 = adaptive must survive the builder");
        // topology + chunk policy ride the comm section into the build
        let comm = CommConfig {
            topology: "hier".into(),
            nodes: 2,
            chunk_policy: "max".into(),
            ..CommConfig::default()
        };
        let b = MoeLayerBuilder::new().comm_config(&comm);
        assert_eq!(b.comm.topology_for(4).unwrap().local_size(), 2);
        assert_eq!(ChunkPolicy::parse(&b.comm.chunk_policy), Some(ChunkPolicy::Max));
    }
}
