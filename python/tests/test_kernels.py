"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes, dtypes and block sizes — the core correctness
signal for the whole stack (the Rust runtime executes HLO lowered from
exactly these kernels).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    combine_rows,
    expert_ffn,
    gate_scores,
    ref,
    scatter_rows,
)

F_DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-5
    )


# ---------------------------------------------------------------------------
# gate_scores
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(1, 200),
    dm=st.integers(1, 96),
    ne=st.integers(1, 32),
    block=st.sampled_from([8, 32, 128]),
    dtype=F_DTYPES,
    seed=st.integers(0, 2**31 - 1),
)
def test_gate_scores_matches_ref(nb, dm, ne, block, dtype, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((nb, dm)), dtype)
    wg = jnp.asarray(r.standard_normal((dm, ne)), dtype)
    bg = jnp.asarray(r.standard_normal(ne), jnp.float32)
    got = gate_scores(x, wg, bg, block_rows=block)
    want = ref.gate_scores_ref(x, wg, bg)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, **_tol(dtype))


# ---------------------------------------------------------------------------
# scatter_rows
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(1, 128),
    dm=st.integers(1, 64),
    n_slots=st.integers(1, 256),
    block=st.sampled_from([8, 64, 128]),
    dtype=F_DTYPES,
    seed=st.integers(0, 2**31 - 1),
)
def test_scatter_rows_matches_ref(nb, dm, n_slots, block, dtype, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((nb, dm)), dtype)
    src = jnp.asarray(r.integers(-1, nb, n_slots), jnp.int32)
    got = scatter_rows(x, src, n_slots=n_slots, block_rows=block)
    want = ref.scatter_rows_ref(x, src, n_slots)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=0, atol=0
    )  # pure data movement must be exact


def test_scatter_all_padding():
    x = jnp.ones((4, 8), jnp.float32)
    src = jnp.full((16,), -1, jnp.int32)
    out = scatter_rows(x, src, n_slots=16)
    assert float(jnp.abs(out).max()) == 0.0


# ---------------------------------------------------------------------------
# combine_rows
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(1, 128),
    dm=st.integers(1, 64),
    n_slots=st.integers(1, 200),
    k=st.integers(1, 4),
    block=st.sampled_from([8, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_combine_rows_matches_ref(nb, dm, n_slots, k, block, seed):
    r = np.random.default_rng(seed)
    y = jnp.asarray(r.standard_normal((n_slots, dm)), jnp.float32)
    # include OOB sentinels (dropped assignments)
    slots = jnp.asarray(r.integers(0, n_slots + 3, (nb, k)), jnp.int32)
    w = jnp.asarray(r.random((nb, k)), jnp.float32)
    got = combine_rows(y, slots, w, block_rows=block)
    want = ref.combine_rows_ref(y, slots, w)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_combine_all_dropped_is_zero():
    y = jnp.ones((8, 4), jnp.float32)
    slots = jnp.full((5, 2), 8, jnp.int32)  # all OOB
    w = jnp.ones((5, 2), jnp.float32)
    out = combine_rows(y, slots, w)
    assert float(jnp.abs(out).max()) == 0.0


# ---------------------------------------------------------------------------
# expert_ffn
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    ne=st.integers(1, 8),
    cap=st.integers(1, 64),
    dm=st.integers(1, 48),
    dh=st.integers(1, 96),
    br=st.sampled_from([8, 16, 128]),
    bh=st.sampled_from([16, 32, 512]),
    dtype=F_DTYPES,
    seed=st.integers(0, 2**31 - 1),
)
def test_expert_ffn_matches_ref(ne, cap, dm, dh, br, bh, dtype, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((ne, cap, dm)), dtype)
    w1 = jnp.asarray(r.standard_normal((ne, dm, dh)) * 0.2, dtype)
    b1 = jnp.asarray(r.standard_normal((ne, dh)) * 0.1, jnp.float32).astype(dtype)
    w2 = jnp.asarray(r.standard_normal((ne, dh, dm)) * 0.2, dtype)
    b2 = jnp.asarray(r.standard_normal((ne, dm)) * 0.1, jnp.float32).astype(dtype)
    got = expert_ffn(x, w1, b1, w2, b2, block_rows=br, block_hidden=bh)
    want = ref.expert_ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_expert_ffn_hidden_accumulation_exact():
    """Tiling the hidden axis must not change the result (k-loop accum)."""
    r = np.random.default_rng(7)
    x = jnp.asarray(r.standard_normal((2, 16, 8)), jnp.float32)
    w1 = jnp.asarray(r.standard_normal((2, 8, 64)) * 0.3, jnp.float32)
    b1 = jnp.asarray(r.standard_normal((2, 64)) * 0.1, jnp.float32)
    w2 = jnp.asarray(r.standard_normal((2, 64, 8)) * 0.3, jnp.float32)
    b2 = jnp.asarray(r.standard_normal((2, 8)) * 0.1, jnp.float32)
    full = expert_ffn(x, w1, b1, w2, b2, block_hidden=64)
    tiled = expert_ffn(x, w1, b1, w2, b2, block_hidden=16)
    np.testing.assert_allclose(full, tiled, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Gradients through the custom VJPs
# ---------------------------------------------------------------------------

def test_gate_scores_grad_matches_ref(rng):
    x = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    wg = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    bg = jnp.asarray(rng.standard_normal(8), jnp.float32)

    def f_kern(x, wg, bg):
        return jnp.sum(jnp.sin(gate_scores(x, wg, bg)))

    def f_ref(x, wg, bg):
        return jnp.sum(jnp.sin(ref.gate_scores_ref(x, wg, bg)))

    g1 = jax.grad(f_kern, argnums=(0, 1, 2))(x, wg, bg)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(x, wg, bg)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_scatter_combine_grads_roundtrip(rng):
    """scatter with a true permutation then combine(k=1, w=1) is identity;
    its gradient must be the identity too."""
    nb, dm = 16, 8
    x = jnp.asarray(rng.standard_normal((nb, dm)), jnp.float32)
    perm = rng.permutation(nb).astype(np.int32)
    src = jnp.asarray(perm)
    slots = jnp.asarray(np.argsort(perm)[:, None].astype(np.int32))
    w = jnp.ones((nb, 1), jnp.float32)

    def f(x):
        xs = scatter_rows(x, src, n_slots=nb)
        return jnp.sum(combine_rows(xs, slots, w) * jnp.arange(nb)[:, None])

    g = jax.grad(f)(x)
    want = jnp.broadcast_to(jnp.arange(nb, dtype=jnp.float32)[:, None], (nb, dm))
    np.testing.assert_allclose(g, want, rtol=1e-6)


def test_expert_ffn_grad_matches_ref(rng):
    ne, cap, dm, dh = 3, 12, 8, 16
    x = jnp.asarray(rng.standard_normal((ne, cap, dm)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((ne, dm, dh)) * 0.3, jnp.float32)
    b1 = jnp.asarray(rng.standard_normal((ne, dh)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((ne, dh, dm)) * 0.3, jnp.float32)
    b2 = jnp.asarray(rng.standard_normal((ne, dm)) * 0.1, jnp.float32)

    def mk(fn):
        def f(*args):
            return 0.5 * jnp.mean(fn(*args) ** 2)
        return f

    g1 = jax.grad(mk(expert_ffn), argnums=tuple(range(5)))(x, w1, b1, w2, b2)
    g2 = jax.grad(mk(ref.expert_ffn_ref), argnums=tuple(range(5)))(x, w1, b1, w2, b2)
    for a, b, nm in zip(g1, g2, ["x", "w1", "b1", "w2", "b2"]):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=1e-6, err_msg=nm)
