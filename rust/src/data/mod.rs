//! Data pipeline: synthetic corpus, byte tokenizer, batching.
//!
//! The paper trains on a proprietary corpus; we substitute a seeded
//! synthetic stream with real learnable structure (DESIGN.md §1): a
//! first-order Markov chain over a byte vocabulary whose transition
//! rows are sparse and Zipf-weighted, overlaid with repeated "phrase"
//! templates.  A language model must learn both the bigram statistics
//! and the phrases, so the lm-loss curve falls the way Figure 7 needs,
//! and a bigger-capacity model (MoE) has headroom to fall further.

use crate::rng::Rng;
use crate::tensor::TensorI32;

/// Synthetic-corpus generator.
pub struct Corpus {
    pub vocab: usize,
    tokens: Vec<u16>,
}

impl Corpus {
    /// Generate `len` tokens over `vocab` symbols from `seed`.
    pub fn synthetic(vocab: usize, len: usize, seed: u64) -> Corpus {
        assert!(vocab >= 8 && vocab <= u16::MAX as usize);
        let mut rng = Rng::new(seed);

        // sparse Zipf-ish Markov chain: each symbol can transition to a
        // few successors with skewed weights
        let fanout = 6.min(vocab - 1);
        let mut succ = vec![0u16; vocab * fanout];
        let mut wts = vec![0f64; fanout];
        for (i, w) in wts.iter_mut().enumerate() {
            *w = 1.0 / (1.0 + i as f64); // Zipf weights shared by all rows
        }
        for s in 0..vocab {
            for f in 0..fanout {
                succ[s * fanout + f] = rng.below(vocab) as u16;
            }
        }

        // a handful of fixed phrases injected repeatedly
        let n_phrases = 8;
        let phrases: Vec<Vec<u16>> = (0..n_phrases)
            .map(|_| {
                let plen = 4 + rng.below(8);
                (0..plen).map(|_| rng.below(vocab) as u16).collect()
            })
            .collect();

        let mut tokens = Vec::with_capacity(len);
        let mut state = rng.below(vocab);
        while tokens.len() < len {
            if rng.bool(0.05) {
                // emit a phrase
                let p = &phrases[rng.below(n_phrases)];
                tokens.extend_from_slice(p);
                state = *p.last().unwrap() as usize;
            } else {
                let f = rng.weighted(&wts);
                let next = succ[state * fanout + f];
                tokens.push(next);
                state = next as usize;
            }
        }
        tokens.truncate(len);
        Corpus { vocab, tokens }
    }

    /// Wrap a byte text (real-data path; vocab 256).
    pub fn from_bytes(text: &[u8]) -> Corpus {
        Corpus { vocab: 256, tokens: text.iter().map(|&b| b as u16).collect() }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn tokens(&self) -> &[u16] {
        &self.tokens
    }
}

/// Byte-level tokenizer (vocab 256) — the real-text pathway.
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn encode(text: &str) -> Vec<u16> {
        text.as_bytes().iter().map(|&b| b as u16).collect()
    }

    pub fn decode(tokens: &[u16]) -> String {
        tokens
            .iter()
            .map(|&t| (t.min(255) as u8) as char)
            .collect()
    }
}

/// One (tokens, targets) LM batch as i32 tensors `[batch, seq]`.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: TensorI32,
    pub targets: TensorI32,
}

/// Deterministic random-window batch sampler over a corpus.
pub struct BatchIter<'a> {
    corpus: &'a Corpus,
    batch: usize,
    seq: usize,
    rng: Rng,
}

impl<'a> BatchIter<'a> {
    pub fn new(corpus: &'a Corpus, batch: usize, seq: usize, seed: u64) -> Self {
        assert!(corpus.len() > seq + 1, "corpus too small for seq {seq}");
        Self { corpus, batch, seq, rng: Rng::new(seed) }
    }

    /// Sample the next batch (windows are i.i.d. uniform over the corpus).
    pub fn next_batch(&mut self) -> Batch {
        let b = self.batch;
        let s = self.seq;
        let mut tok = vec![0i32; b * s];
        let mut tgt = vec![0i32; b * s];
        for r in 0..b {
            let start = self.rng.below(self.corpus.len() - s - 1);
            for c in 0..s {
                tok[r * s + c] = self.corpus.tokens[start + c] as i32;
                tgt[r * s + c] = self.corpus.tokens[start + c + 1] as i32;
            }
        }
        Batch {
            tokens: TensorI32 { shape: vec![b, s], data: tok },
            targets: TensorI32 { shape: vec![b, s], data: tgt },
        }
    }

    /// A worker-disjoint shard iterator (data parallelism): fork the RNG
    /// per rank so each worker draws different windows.
    pub fn shard(corpus: &'a Corpus, batch: usize, seq: usize, seed: u64, rank: usize) -> Self {
        Self::new(corpus, batch, seq, seed ^ ((rank as u64 + 1) * 0x9E37_79B9))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_deterministic_and_in_range() {
        let a = Corpus::synthetic(64, 10_000, 3);
        let b = Corpus::synthetic(64, 10_000, 3);
        assert_eq!(a.tokens, b.tokens);
        assert!(a.tokens.iter().all(|&t| (t as usize) < 64));
        let c = Corpus::synthetic(64, 10_000, 4);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn corpus_has_structure() {
        // bigram entropy must be well below the uniform bound — that's
        // what makes the lm loss learnable
        let c = Corpus::synthetic(64, 200_000, 7);
        let mut uni = vec![0f64; 64];
        let mut big = std::collections::HashMap::new();
        for w in c.tokens.windows(2) {
            uni[w[0] as usize] += 1.0;
            *big.entry((w[0], w[1])).or_insert(0f64) += 1.0;
        }
        let n = (c.tokens.len() - 1) as f64;
        let h_uni: f64 = uni
            .iter()
            .filter(|&&x| x > 0.0)
            .map(|&x| -(x / n) * (x / n).log2())
            .sum();
        let h_joint: f64 = big
            .values()
            .map(|&x| -(x / n) * (x / n).log2())
            .sum();
        let h_cond = h_joint - h_uni;
        assert!(h_cond < 0.8 * (64f64).log2(), "h_cond={h_cond}");
        assert!(h_cond > 0.5, "too deterministic: {h_cond}");
    }

    #[test]
    fn batches_are_shifted_windows() {
        let c = Corpus::synthetic(32, 5_000, 1);
        let mut it = BatchIter::new(&c, 3, 16, 9);
        let b = it.next_batch();
        assert_eq!(b.tokens.shape, vec![3, 16]);
        for r in 0..3 {
            for i in 0..15 {
                assert_eq!(b.tokens.data[r * 16 + i + 1], b.targets.data[r * 16 + i]);
            }
        }
        // deterministic given the seed
        let mut it2 = BatchIter::new(&c, 3, 16, 9);
        assert_eq!(it2.next_batch().tokens.data, b.tokens.data);
    }

    #[test]
    fn shards_draw_different_windows() {
        let c = Corpus::synthetic(32, 5_000, 1);
        let b0 = BatchIter::shard(&c, 2, 16, 5, 0).next_batch();
        let b1 = BatchIter::shard(&c, 2, 16, 5, 1).next_batch();
        assert_ne!(b0.tokens.data, b1.tokens.data);
    }

    #[test]
    fn tokenizer_roundtrip_ascii() {
        let text = "FastMoE: scatter / gather!";
        let toks = ByteTokenizer::encode(text);
        assert_eq!(ByteTokenizer::decode(&toks), text);
    }

    #[test]
    #[should_panic]
    fn corpus_too_small_panics() {
        let c = Corpus::synthetic(32, 10, 1);
        let _ = BatchIter::new(&c, 1, 16, 0);
    }
}
