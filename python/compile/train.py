"""Fused train/eval steps (Layer-2) lowered once by aot.py.

The train step is one HLO program: forward, backward, and the Adam
update.  The Rust coordinator owns the parameter and optimizer tensors
and calls this executable with them positionally every iteration —
python is never on the iteration path.

Positional ABI (recorded in the manifest and relied on by
``rust/src/model``):

    inputs  = [tokens, targets, step] + params + m + v
    outputs = (loss,) + new_params + new_m + new_v

where ``params``/``m``/``v`` follow the registry order of
:func:`compile.gpt.param_specs`.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from . import gpt


ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def adam_update(p, g, m, v, step, lr: float, weight_decay: float = 0.0):
    """Single-tensor Adam with bias correction; matches rust/src/model/adam.rs."""
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / (1.0 - ADAM_B1**step)
    vhat = v / (1.0 - ADAM_B2**step)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + ADAM_EPS) + weight_decay * p)
    return p, m, v


def make_train_step(cfg: gpt.GptConfig, lr: float = 3e-4,
                    *, interpret: bool = True, balance_coef: float = 0.0):
    """Return ``step(tokens, targets, step_no, *flat_state)`` for lowering.

    ``balance_coef > 0`` adds the GShard load-balance auxiliary loss
    (the paper's §6 future-work feature)."""
    specs = gpt.param_specs(cfg)
    names = [s.name for s in specs]
    n = len(names)

    def unflatten(flat: List[jax.Array]) -> Dict[str, jax.Array]:
        return dict(zip(names, flat))

    def step_fn(tokens, targets, step_no, *flat_state):
        assert len(flat_state) == 3 * n
        params = unflatten(list(flat_state[:n]))
        m_st = list(flat_state[n : 2 * n])
        v_st = list(flat_state[2 * n :])

        def loss_fn(p):
            return gpt.lm_loss(p, tokens, targets, cfg, interpret=interpret,
                               balance_coef=balance_coef)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_p, new_m, new_v = [], [], []
        for i, name in enumerate(names):
            p2, m2, v2 = adam_update(
                params[name], grads[name], m_st[i], v_st[i], step_no, lr
            )
            new_p.append(p2)
            new_m.append(m2)
            new_v.append(v2)
        return tuple([loss] + new_p + new_m + new_v)

    return step_fn, specs


def make_eval_step(cfg: gpt.GptConfig, *, interpret: bool = True):
    """Return ``eval(tokens, targets, *params) -> (loss,)`` for lowering."""
    specs = gpt.param_specs(cfg)
    names = [s.name for s in specs]

    def eval_fn(tokens, targets, *flat_params):
        params = dict(zip(names, flat_params))
        return (gpt.lm_loss(params, tokens, targets, cfg, interpret=interpret),)

    return eval_fn, specs


def make_grad_step(cfg: gpt.GptConfig, *, interpret: bool = True):
    """Return ``grad(tokens, targets, *params) -> (loss, *grads)``.

    Used by the *distributed* fig-7 path: each worker computes grads on
    its shard of the batch; the Rust ``GradSync`` all-reduces them by tag
    and the host-side Adam (rust/src/model/adam.rs) applies the update.
    """
    specs = gpt.param_specs(cfg)
    names = [s.name for s in specs]

    def grad_fn(tokens, targets, *flat_params):
        params = dict(zip(names, flat_params))

        def loss_fn(p):
            return gpt.lm_loss(p, tokens, targets, cfg, interpret=interpret)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return tuple([loss] + [grads[nm] for nm in names])

    return grad_fn, specs
