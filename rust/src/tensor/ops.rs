//! Host-side tensor math.
//!
//! Used by the coordinator (gradient averaging, host Adam, gating
//! softmax) and by tests as a slow-but-obvious reference for the XLA
//! artifacts.  The hot paths the paper cares about run inside XLA; these
//! loops only touch coordinator-sized data.

use super::TensorF32;
use crate::error::{Error, Result};

/// `a += b` elementwise.
pub fn add_assign(a: &mut TensorF32, b: &TensorF32) -> Result<()> {
    if a.shape != b.shape {
        return Err(Error::Shape(format!(
            "add_assign {:?} vs {:?}",
            a.shape, b.shape
        )));
    }
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += y;
    }
    Ok(())
}

/// `a *= s` elementwise.
pub fn scale(a: &mut TensorF32, s: f32) {
    for x in a.data.iter_mut() {
        *x *= s;
    }
}

/// `a += alpha * b` (axpy).
pub fn axpy(a: &mut TensorF32, alpha: f32, b: &TensorF32) -> Result<()> {
    if a.shape != b.shape {
        return Err(Error::Shape(format!("axpy {:?} vs {:?}", a.shape, b.shape)));
    }
    for (x, y) in a.data.iter_mut().zip(&b.data) {
        *x += alpha * y;
    }
    Ok(())
}

/// Naive reference matmul `[m,k] @ [k,n] -> [m,n]` (tests / tiny sizes).
pub fn matmul(a: &TensorF32, b: &TensorF32) -> Result<TensorF32> {
    let (m, k) = a.dims2()?;
    let (k2, n) = b.dims2()?;
    if k != k2 {
        return Err(Error::Shape(format!("matmul inner {k} vs {k2}")));
    }
    let mut out = TensorF32::zeros(&[m, n]);
    for i in 0..m {
        for p in 0..k {
            let av = a.data[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[p * n..(p + 1) * n];
            let orow = &mut out.data[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    Ok(out)
}

/// Row-wise softmax in place over the last axis of a rank-2 tensor.
pub fn softmax_rows(t: &mut TensorF32) -> Result<()> {
    let (r, c) = t.dims2()?;
    for i in 0..r {
        let row = &mut t.data[i * c..(i + 1) * c];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for x in row.iter_mut() {
            *x = (*x - mx).exp();
            sum += *x;
        }
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
    Ok(())
}

/// Softmax of a small slice (used for k-way gate weights).
pub fn softmax_slice(xs: &mut [f32]) {
    let mx = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - mx).exp();
        sum += *x;
    }
    for x in xs.iter_mut() {
        *x /= sum;
    }
}

/// Backward of `softmax_slice`: given `w = softmax(s)` and `dw`,
/// `ds_i = w_i * (dw_i - Σ_j w_j dw_j)`.
pub fn softmax_slice_bwd(w: &[f32], dw: &[f32], ds: &mut [f32]) {
    let dot: f32 = w.iter().zip(dw).map(|(a, b)| a * b).sum();
    for i in 0..w.len() {
        ds[i] = w[i] * (dw[i] - dot);
    }
}

/// Indices of the top-k values of a row, ties broken toward the lower
/// index (matches `jax.lax.top_k`).
pub fn topk_indices(row: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| {
        row[b].partial_cmp(&row[a]).unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// Mean of all elements.
pub fn mean(t: &TensorF32) -> f32 {
    if t.data.is_empty() {
        return 0.0;
    }
    t.data.iter().sum::<f32>() / t.data.len() as f32
}

/// Max absolute difference between two tensors (test helper).
pub fn max_abs_diff(a: &TensorF32, b: &TensorF32) -> Result<f32> {
    if a.shape != b.shape {
        return Err(Error::Shape(format!(
            "max_abs_diff {:?} vs {:?}",
            a.shape, b.shape
        )));
    }
    Ok(a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max))
}

/// Copy row `src_row` of `src` into row `dst_row` of `dst` (pack helper).
pub fn copy_row(dst: &mut TensorF32, dst_row: usize, src: &TensorF32, src_row: usize) {
    let c = src.shape[1];
    debug_assert_eq!(dst.shape[1], c);
    let s = &src.data[src_row * c..(src_row + 1) * c];
    dst.data[dst_row * c..(dst_row + 1) * c].copy_from_slice(s);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(rows: usize, cols: usize, v: Vec<f32>) -> TensorF32 {
        TensorF32::from_vec(&[rows, cols], v).unwrap()
    }

    #[test]
    fn matmul_small() {
        let a = t2(2, 2, vec![1., 2., 3., 4.]);
        let b = t2(2, 2, vec![1., 0., 0., 1.]);
        assert_eq!(matmul(&a, &b).unwrap(), a);
        let c = matmul(&a, &a).unwrap();
        assert_eq!(c.data, vec![7., 10., 15., 22.]);
    }

    #[test]
    fn matmul_shape_errors() {
        let a = t2(2, 3, vec![0.0; 6]);
        let b = t2(2, 3, vec![0.0; 6]);
        assert!(matmul(&a, &b).is_err());
    }

    #[test]
    fn softmax_rows_normalises() {
        let mut t = t2(2, 3, vec![1., 2., 3., 1000., 1000., 1000.]);
        softmax_rows(&mut t).unwrap();
        for r in 0..2 {
            let s: f32 = t.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        // large inputs must not overflow
        assert!((t.data[3] - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_bwd_matches_finite_diff() {
        let s = [0.3f32, -0.7, 1.1];
        let dw = [0.5f32, -0.2, 0.9];
        let mut w = s;
        softmax_slice(&mut w);
        let mut ds = [0.0f32; 3];
        softmax_slice_bwd(&w, &dw, &mut ds);
        let eps = 1e-3f32;
        for i in 0..3 {
            let mut sp = s;
            sp[i] += eps;
            let mut wp = sp;
            softmax_slice(&mut wp);
            let mut sm = s;
            sm[i] -= eps;
            let mut wm = sm;
            softmax_slice(&mut wm);
            let fd: f32 = (0..3).map(|j| (wp[j] - wm[j]) / (2.0 * eps) * dw[j]).sum();
            assert!((fd - ds[i]).abs() < 1e-3, "i={i} fd={fd} ds={}", ds[i]);
        }
    }

    #[test]
    fn topk_matches_sort_and_tiebreak() {
        assert_eq!(topk_indices(&[1.0, 3.0, 2.0], 2), vec![1, 2]);
        assert_eq!(topk_indices(&[5.0, 5.0, 1.0], 2), vec![0, 1]); // tie -> lower idx
        assert_eq!(topk_indices(&[2.0], 1), vec![0]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = t2(1, 3, vec![1., 2., 3.]);
        let b = t2(1, 3, vec![10., 10., 10.]);
        axpy(&mut a, 0.5, &b).unwrap();
        assert_eq!(a.data, vec![6., 7., 8.]);
        scale(&mut a, 2.0);
        assert_eq!(a.data, vec![12., 14., 16.]);
    }

    #[test]
    fn copy_row_moves_data() {
        let src = t2(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let mut dst = TensorF32::zeros(&[2, 3]);
        copy_row(&mut dst, 0, &src, 1);
        assert_eq!(dst.row(0), &[4., 5., 6.]);
        assert_eq!(dst.row(1), &[0., 0., 0.]);
    }
}
