"""The GPT model of §5.4: a Megatron-style decoder with MoE or dense FFNs.

Parameters live in an explicit *registry* — an ordered list of
``(name, shape, init, sync_tag)`` — rather than an opaque pytree, because
the Rust coordinator owns the parameter store at run time: it initialises
tensors from the manifest (never calling python), feeds them to the
train-step executable positionally, and synchronises gradients according
to the FastMoE §3.2 tags:

* ``world``          — replicated everywhere (the gate), all-reduce over
                       all workers;
* ``data_parallel``  — replicated within a DP group (attention, norms,
                       embeddings);
* ``none``           — expert-parallel shards, never synchronised.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import layers


@dataclasses.dataclass(frozen=True)
class GptConfig:
    """Model hyper-parameters (mirrors rust/src/config)."""

    vocab: int = 256
    seq: int = 128
    n_layer: int = 4
    d_model: int = 256
    n_head: int = 8
    d_hidden: int = 1024        # dense FFN hidden size
    moe: bool = True
    n_expert: int = 16          # global expert count when moe=True
    top_k: int = 2
    capacity_factor: float = 1.25
    # When moe=True the hidden size is divided so that per-token FLOPs
    # match the dense baseline with top_k experts active (§5.4: "d_h …
    # halved so that the valid FLOPs of the model are almost identical").
    @property
    def d_hidden_expert(self) -> int:
        return max(8, self.d_hidden // self.top_k)

    @property
    def tokens_per_batch(self) -> int:
        return self.seq

    def capacity(self, n_tokens: int) -> int:
        return layers.capacity_for(
            n_tokens, self.top_k, self.n_expert, self.capacity_factor
        )


# ---------------------------------------------------------------------------
# Parameter registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    init: str          # "normal:<std>" | "zeros" | "ones"
    tag: str           # "world" | "data_parallel" | "none"


def param_specs(cfg: GptConfig) -> List[ParamSpec]:
    """The ordered parameter registry for a model config."""
    P: List[ParamSpec] = []
    d, dh, v = cfg.d_model, cfg.d_hidden, cfg.vocab
    std = 0.02
    resid_std = std / max(1.0, (2 * cfg.n_layer) ** 0.5)

    P.append(ParamSpec("embed/tok", (v, d), f"normal:{std}", "data_parallel"))
    P.append(ParamSpec("embed/pos", (cfg.seq, d), f"normal:{std}", "data_parallel"))
    for l in range(cfg.n_layer):
        pre = f"layer{l}"
        P += [
            ParamSpec(f"{pre}/ln1/g", (d,), "ones", "data_parallel"),
            ParamSpec(f"{pre}/ln1/b", (d,), "zeros", "data_parallel"),
            ParamSpec(f"{pre}/attn/wqkv", (d, 3 * d), f"normal:{std}", "data_parallel"),
            ParamSpec(f"{pre}/attn/bqkv", (3 * d,), "zeros", "data_parallel"),
            ParamSpec(f"{pre}/attn/wo", (d, d), f"normal:{resid_std}", "data_parallel"),
            ParamSpec(f"{pre}/attn/bo", (d,), "zeros", "data_parallel"),
            ParamSpec(f"{pre}/ln2/g", (d,), "ones", "data_parallel"),
            ParamSpec(f"{pre}/ln2/b", (d,), "zeros", "data_parallel"),
        ]
        if cfg.moe:
            de = cfg.d_hidden_expert
            ne = cfg.n_expert
            P += [
                ParamSpec(f"{pre}/moe/gate/w", (d, ne), f"normal:{std}", "world"),
                ParamSpec(f"{pre}/moe/gate/b", (ne,), "zeros", "world"),
                ParamSpec(f"{pre}/moe/expert/w1", (ne, d, de), f"normal:{std}", "none"),
                ParamSpec(f"{pre}/moe/expert/b1", (ne, de), "zeros", "none"),
                ParamSpec(f"{pre}/moe/expert/w2", (ne, de, d), f"normal:{resid_std}", "none"),
                ParamSpec(f"{pre}/moe/expert/b2", (ne, d), "zeros", "none"),
            ]
        else:
            P += [
                ParamSpec(f"{pre}/ffn/w1", (d, dh), f"normal:{std}", "data_parallel"),
                ParamSpec(f"{pre}/ffn/b1", (dh,), "zeros", "data_parallel"),
                ParamSpec(f"{pre}/ffn/w2", (dh, d), f"normal:{resid_std}", "data_parallel"),
                ParamSpec(f"{pre}/ffn/b2", (d,), "zeros", "data_parallel"),
            ]
    P += [
        ParamSpec("final_ln/g", (d,), "ones", "data_parallel"),
        ParamSpec("final_ln/b", (d,), "zeros", "data_parallel"),
        ParamSpec("head/w", (d, v), f"normal:{std}", "data_parallel"),
    ]
    return P


def init_params(cfg: GptConfig, key) -> Dict[str, jax.Array]:
    """Initialise parameters per the registry (python-side mirror of the
    Rust initialiser; used only by python tests)."""
    out = {}
    for spec in param_specs(cfg):
        key, sub = jax.random.split(key)
        if spec.init == "zeros":
            out[spec.name] = jnp.zeros(spec.shape, jnp.float32)
        elif spec.init == "ones":
            out[spec.name] = jnp.ones(spec.shape, jnp.float32)
        else:
            std = float(spec.init.split(":")[1])
            out[spec.name] = std * jax.random.normal(sub, spec.shape, jnp.float32)
    return out


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def gpt_logits(params: Dict[str, jax.Array], tokens, cfg: GptConfig,
               *, interpret: bool = True, with_aux: bool = False):
    """Forward over ``tokens: [batch, seq] int32`` -> ``[batch, seq, vocab]``.

    The MoE FFN flattens (batch, seq) into one token batch so experts see
    a single contiguous GEMM per layer — exactly the paper's batching
    principle.
    """
    b, s = tokens.shape
    assert s == cfg.seq, f"seq {s} != cfg.seq {cfg.seq}"
    x = params["embed/tok"][tokens] + params["embed/pos"][None, :, :]

    n_tok = b * s
    cap = cfg.capacity(n_tok)
    aux_total = jnp.float32(0.0)
    for l in range(cfg.n_layer):
        pre = f"layer{l}"
        h = layers.layernorm(x, params[f"{pre}/ln1/g"], params[f"{pre}/ln1/b"])
        att = jax.vmap(
            lambda hh: layers.causal_attention(
                hh,
                params[f"{pre}/attn/wqkv"],
                params[f"{pre}/attn/bqkv"],
                params[f"{pre}/attn/wo"],
                params[f"{pre}/attn/bo"],
                cfg.n_head,
            )
        )(h)
        x = x + att
        h = layers.layernorm(x, params[f"{pre}/ln2/g"], params[f"{pre}/ln2/b"])
        flat = h.reshape(n_tok, cfg.d_model)
        if cfg.moe:
            margs = (
                flat,
                params[f"{pre}/moe/gate/w"],
                params[f"{pre}/moe/gate/b"],
                params[f"{pre}/moe/expert/w1"],
                params[f"{pre}/moe/expert/b1"],
                params[f"{pre}/moe/expert/w2"],
                params[f"{pre}/moe/expert/b2"],
            )
            if with_aux:
                y, aux = layers.moe_ffn_with_aux(
                    *margs, k=cfg.top_k, capacity=cap, interpret=interpret
                )
                aux_total = aux_total + aux
            else:
                y = layers.moe_ffn(
                    *margs, k=cfg.top_k, capacity=cap, interpret=interpret
                )
        else:
            y = layers.dense_ffn(
                flat,
                params[f"{pre}/ffn/w1"],
                params[f"{pre}/ffn/b1"],
                params[f"{pre}/ffn/w2"],
                params[f"{pre}/ffn/b2"],
            )
        x = x + y.reshape(b, s, cfg.d_model)

    x = layers.layernorm(x, params["final_ln/g"], params["final_ln/b"])
    logits = x @ params["head/w"]
    if with_aux:
        return logits, aux_total / max(1, cfg.n_layer)
    return logits


def lm_loss(params, tokens, targets, cfg: GptConfig, *, interpret: bool = True,
            balance_coef: float = 0.0):
    """Mean cross-entropy next-token loss (the paper's ``lm loss``),
    optionally plus the GShard balance loss (§6 future work)."""
    if balance_coef > 0.0:
        logits, aux = gpt_logits(params, tokens, cfg, interpret=interpret,
                                 with_aux=True)
    else:
        logits = gpt_logits(params, tokens, cfg, interpret=interpret)
        aux = 0.0
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + balance_coef * aux


def model_flops_per_token(cfg: GptConfig) -> int:
    """Matmul FLOPs per token per fwd pass (the paper's Fig-6 metric)."""
    d, s = cfg.d_model, cfg.seq
    attn = 2 * d * 3 * d + 2 * s * d + 2 * s * d + 2 * d * d  # qkv + scores + av + proj
    if cfg.moe:
        ffn = cfg.top_k * (2 * d * cfg.d_hidden_expert * 2)
        gate = 2 * d * cfg.n_expert
    else:
        ffn = 2 * d * cfg.d_hidden * 2
        gate = 0
    head = 2 * d * cfg.vocab
    return cfg.n_layer * (attn + ffn + gate) + head
