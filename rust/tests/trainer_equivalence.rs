//! The two training ABIs must agree: the fused in-graph train step
//! (tokens→new params, Adam inside XLA) and the distributed path
//! (grad_step artifact + GradSync + host Adam) are the same math.

use std::sync::Arc;

use fastmoe::comm::{run_workers, Comm};
use fastmoe::coordinator::{DistTrainer, Trainer};
use fastmoe::data::{BatchIter, Corpus};
use fastmoe::runtime::Runtime;
use fastmoe::tensor::ops;

fn runtime() -> Option<Arc<Runtime>> {
    Runtime::open_default().ok().map(Arc::new)
}

#[test]
fn host_adam_path_equals_fused_path() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let model = "gpt_moe";
    let seed = 33;
    let steps = 3;

    // --- fused path ---
    let mut fused = Trainer::new(&rt, model, seed).unwrap();
    let vocab = fused.entry.config_usize("vocab").unwrap();
    let seq = fused.entry.config_usize("seq").unwrap();
    let batch = fused.entry.config_usize("batch").unwrap();
    let lr = 3e-4f32; // the preset lr used when lowering train_step
    let corpus = Corpus::synthetic(vocab, 100_000, 9);
    let mut it = BatchIter::new(&corpus, batch, seq, 21);
    let batches: Vec<_> = (0..steps).map(|_| it.next_batch()).collect();
    let mut fused_losses = Vec::new();
    for b in &batches {
        fused_losses.push(fused.train_step(b).unwrap().loss);
    }

    // --- distributed path, world size 1 (no sync effects) ---
    let rt2 = rt.clone();
    let batches2 = batches.clone();
    let (dist_losses, dist_params) = run_workers(1, move |mut h| {
        let mut tr = DistTrainer::new(&rt2, "gpt_moe", seed, 1, lr)?;
        let mut losses = Vec::new();
        for b in &batches2 {
            losses.push(tr.train_step(&mut h, b)?);
        }
        Ok((losses, tr.params))
    })
    .unwrap()
    .remove(0);

    for (s, (a, b)) in fused_losses.iter().zip(&dist_losses).enumerate() {
        assert!(
            (a - b).abs() < 1e-4 * (1.0 + a.abs()),
            "step {s}: fused loss {a} vs dist {b}"
        );
    }
    // parameters agree after `steps` updates
    for (i, (a, b)) in fused
        .params
        .tensors
        .iter()
        .zip(&dist_params.tensors)
        .enumerate()
    {
        let diff = ops::max_abs_diff(a, b).unwrap();
        let scale = 1e-3 + b.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(
            diff < 2e-3 * scale,
            "param {} (`{}`): diff {diff}",
            i,
            fused.params.entries[i].name
        );
    }
}

#[test]
fn multi_worker_training_decreases_loss_and_stays_in_sync() {
    let Some(rt) = runtime() else { return };
    let workers = 2;
    let out = run_workers(workers, {
        let rt = rt.clone();
        move |mut h| {
            let mut tr = DistTrainer::new(&rt, "gpt_moe", 77, workers, 1e-3)?;
            let vocab = tr.entry.config_usize("vocab").unwrap();
            let seq = tr.entry.config_usize("seq").unwrap();
            let batch = tr.entry.config_usize("batch").unwrap();
            let corpus = Corpus::synthetic(vocab, 100_000, 4);
            let mut it = BatchIter::shard(&corpus, batch, seq, 10, h.rank());
            let mut losses = Vec::new();
            for _ in 0..6 {
                losses.push(tr.train_step(&mut h, &it.next_batch())?);
            }
            Ok((losses, tr.params))
        }
    })
    .unwrap();

    let (l0, p0) = &out[0];
    let (l1, p1) = &out[1];
    // both workers report the identical global loss
    for (a, b) in l0.iter().zip(l1) {
        assert_eq!(a, b, "global loss must be identical on all workers");
    }
    assert!(l0.last().unwrap() < l0.first().unwrap(), "{l0:?}");
    // replicated parameters stay bit-identical across workers
    for (i, (a, b)) in p0.tensors.iter().zip(&p1.tensors).enumerate() {
        let diff = ops::max_abs_diff(a, b).unwrap();
        assert!(
            diff < 1e-6,
            "param {} (`{}`) diverged across workers: {diff}",
            i,
            p0.entries[i].name
        );
    }
}
