//! Analytic α-β network timing model.
//!
//! The paper's testbed is 8 nodes × 1 V100 over Infiniband EDR.  Our
//! in-process channels move data at memcpy speed, so for the Figure-6
//! scalability study we account *simulated wire time* for each
//! collective with the classic latency/bandwidth (α-β) model:
//!
//!   t(message of b bytes) = α + b / β
//!
//! All-to-all across `n` workers sends `n-1` messages per worker in
//! parallel network directions; with a non-blocking switch (the paper's
//! EDR switch + 8 HCAs) each worker's egress is the bottleneck:
//!
//!   t_a2a = α·(n-1) + (bytes_sent_by_worker) / β
//!
//! Ring all-reduce of `s` bytes: 2(n-1) steps of s/n bytes each.
//!
//! Overlapped MoE steps (the `[comm] overlap` pipeline) are scored as
//! `max(wire, compute)` per chunk with fill/drain ends — see
//! [`NetModel::moe_step_overlapped`] vs the blocking
//! [`NetModel::moe_step_blocking`] — so Figure 6 reflects the win of
//! hiding the global exchange behind expert computation.

/// Preset link parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetPreset {
    /// Infiniband EDR: 100 Gb/s ≈ 12.5 GB/s, ~1.5 µs MPI-level latency.
    IbEdr,
    /// PCIe 3.0 x16 host link: ~12 GB/s but higher software latency.
    Pcie3,
    /// Infinite network (disable simulated wire time).
    None,
}

impl NetPreset {
    pub fn parse(s: &str) -> Option<NetPreset> {
        match s {
            "ib-edr" | "ib_edr" | "ib" => Some(NetPreset::IbEdr),
            "pcie3" | "pcie" => Some(NetPreset::Pcie3),
            "none" | "infinite" => Some(NetPreset::None),
            _ => None,
        }
    }
}

/// The α-β model with per-collective helpers.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// Per-message latency, seconds.
    pub alpha: f64,
    /// Link bandwidth, bytes/second.
    pub beta: f64,
    pub enabled: bool,
}

impl NetModel {
    pub fn preset(p: NetPreset) -> NetModel {
        match p {
            NetPreset::IbEdr => NetModel {
                alpha: 1.5e-6,
                beta: 12.5e9,
                enabled: true,
            },
            NetPreset::Pcie3 => NetModel {
                alpha: 5.0e-6,
                beta: 12.0e9,
                enabled: true,
            },
            NetPreset::None => NetModel { alpha: 0.0, beta: f64::INFINITY, enabled: false },
        }
    }

    /// Wire time of one point-to-point message.
    pub fn p2p(&self, bytes: usize) -> f64 {
        if !self.enabled {
            return 0.0;
        }
        self.alpha + bytes as f64 / self.beta
    }

    /// All-to-all among `n` workers where this worker sends
    /// `bytes_out` in total (egress-bound, non-blocking switch).
    pub fn all_to_all(&self, n: usize, bytes_out: usize) -> f64 {
        if !self.enabled || n <= 1 {
            return 0.0;
        }
        self.alpha * (n - 1) as f64 + bytes_out as f64 / self.beta
    }

    /// Ring all-reduce of a `bytes`-sized buffer among `n` workers.
    pub fn all_reduce(&self, n: usize, bytes: usize) -> f64 {
        if !self.enabled || n <= 1 {
            return 0.0;
        }
        let steps = 2 * (n - 1);
        let per_step = bytes as f64 / n as f64;
        steps as f64 * (self.alpha + per_step / self.beta)
    }

    /// One blocking MoE exchange+compute phase: the full all-to-all
    /// (`bytes_out` egress) strictly before `compute` seconds of
    /// expert work — the `chunks = 1` baseline the paper improves on.
    pub fn moe_step_blocking(&self, n: usize, bytes_out: usize, compute: f64) -> f64 {
        self.all_to_all(n, bytes_out) + compute
    }

    /// The same phase pipelined over `chunks` ring-offset peer groups:
    /// chunk `i+1`'s wire time hides behind chunk `i`'s compute (and
    /// vice versa), so steady state costs `max(wire, compute)` per
    /// chunk, plus one wire fill and one compute drain at the ends:
    ///
    /// ```text
    /// t = w + (C−1)·max(w, k) + k,   w = wire/C,  k = compute/C
    /// ```
    ///
    /// `chunks = 1` degenerates to [`NetModel::moe_step_blocking`]
    /// exactly; with both wire and compute nonzero and `chunks > 1`
    /// the pipelined time is strictly lower.
    pub fn moe_step_overlapped(
        &self,
        n: usize,
        bytes_out: usize,
        compute: f64,
        chunks: usize,
    ) -> f64 {
        if !self.enabled || n <= 1 {
            return compute;
        }
        let c = chunks.clamp(1, n) as f64;
        let wire_chunk =
            self.alpha * ((n - 1) as f64 / c) + bytes_out as f64 / self.beta / c;
        let comp_chunk = compute / c;
        wire_chunk + (c - 1.0) * wire_chunk.max(comp_chunk) + comp_chunk
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse() {
        assert_eq!(NetPreset::parse("ib-edr"), Some(NetPreset::IbEdr));
        assert_eq!(NetPreset::parse("none"), Some(NetPreset::None));
        assert_eq!(NetPreset::parse("smoke-signal"), None);
    }

    #[test]
    fn p2p_scales_with_bytes() {
        let m = NetModel::preset(NetPreset::IbEdr);
        let t1 = m.p2p(1 << 20);
        let t2 = m.p2p(2 << 20);
        assert!(t2 > t1);
        // 1 MiB at 12.5 GB/s ≈ 84 µs ≫ α
        assert!((t1 - (1.5e-6 + 1048576.0 / 12.5e9)).abs() < 1e-12);
    }

    #[test]
    fn disabled_is_free() {
        let m = NetModel::preset(NetPreset::None);
        assert_eq!(m.p2p(usize::MAX / 2), 0.0);
        assert_eq!(m.all_to_all(8, 1 << 30), 0.0);
        assert_eq!(m.all_reduce(8, 1 << 30), 0.0);
    }

    #[test]
    fn all_reduce_bandwidth_term_shrinks_with_n() {
        let m = NetModel::preset(NetPreset::IbEdr);
        let big = 256 << 20;
        // 2(n-1)/n · s/β is increasing in n but bounded by 2s/β
        let t2 = m.all_reduce(2, big);
        let t8 = m.all_reduce(8, big);
        assert!(t8 > t2);
        assert!(t8 < 2.0 * big as f64 / m.beta + 16.0 * m.alpha);
    }

    #[test]
    fn single_worker_is_free() {
        let m = NetModel::preset(NetPreset::IbEdr);
        assert_eq!(m.all_to_all(1, 123), 0.0);
        assert_eq!(m.all_reduce(1, 123), 0.0);
    }

    #[test]
    fn overlap_one_chunk_equals_blocking() {
        let m = NetModel::preset(NetPreset::IbEdr);
        let (n, bytes, compute) = (8usize, 4 << 20, 3e-3);
        let blocking = m.moe_step_blocking(n, bytes, compute);
        let degenerate = m.moe_step_overlapped(n, bytes, compute, 1);
        assert!((blocking - degenerate).abs() < 1e-15);
    }

    #[test]
    fn overlap_strictly_beats_blocking_with_work_on_both_sides() {
        // the acceptance property: at ≥ 4 workers, nonzero wire and
        // compute, chunked pipelining must score strictly lower
        let m = NetModel::preset(NetPreset::IbEdr);
        for n in [4usize, 8, 16] {
            for chunks in [2usize, 4] {
                for compute in [1e-4, 1e-2] {
                    let bytes = 8 << 20;
                    let blocking = m.moe_step_blocking(n, bytes, compute);
                    let overlapped = m.moe_step_overlapped(n, bytes, compute, chunks);
                    assert!(
                        overlapped < blocking,
                        "n={n} chunks={chunks} compute={compute}: \
                         {overlapped} !< {blocking}"
                    );
                    // and never better than the max(wire, compute) bound
                    assert!(
                        overlapped >= m.all_to_all(n, bytes).max(compute) - 1e-15,
                        "pipeline cannot beat its longest stage"
                    );
                }
            }
        }
    }

    #[test]
    fn overlap_disabled_net_is_pure_compute() {
        let m = NetModel::preset(NetPreset::None);
        assert_eq!(m.moe_step_overlapped(8, 1 << 30, 2.5, 4), 2.5);
        assert_eq!(m.moe_step_blocking(8, 1 << 30, 2.5), 2.5);
    }
}
